//! Synchronization semantics: BSP, ASP, and SSP (bounded staleness).
//!
//! The paper evaluates dynamic batching primarily under BSP (where
//! stragglers directly inflate iteration time) and argues it also
//! ameliorates ASP staleness (§III-B).  SSP is included as the natural
//! extension discussed in related work (Ho et al. '13).
//!
//! These types provide the *accounting*: given per-worker progress, who
//! may proceed, what the staleness of an update is, and how much
//! statistical efficiency a stale update retains.  The unified
//! [`crate::session::Session`] loop drives them for every backend —
//! virtual-time simulation and the real PJRT runtime share one gating
//! code path.

/// Synchronization mode of a training run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncMode {
    /// Bulk Synchronous Parallel: a barrier every iteration.
    Bsp,
    /// Asynchronous Parallel: no barrier; updates applied as they arrive.
    Asp,
    /// Stale Synchronous Parallel: fastest may lead slowest by ≤ bound.
    Ssp { bound: u64 },
}

impl SyncMode {
    pub fn parse(s: &str) -> Option<SyncMode> {
        match s {
            "bsp" => Some(SyncMode::Bsp),
            "asp" => Some(SyncMode::Asp),
            _ => s
                .strip_prefix("ssp:")
                .and_then(|b| b.parse().ok())
                .map(|bound| SyncMode::Ssp { bound }),
        }
    }

    pub fn label(&self) -> String {
        match self {
            SyncMode::Bsp => "bsp".into(),
            SyncMode::Asp => "asp".into(),
            SyncMode::Ssp { bound } => format!("ssp:{bound}"),
        }
    }
}

/// Tracks per-worker clock (completed iterations) and enforces the gate.
///
/// Membership is *epoch-tagged*: a worker can be retired (spot
/// revocation) or admitted (recovery / scheduled mid-run join), and every
/// aggregate — `min_clock`, `max_clock`, the BSP barrier — counts only
/// live workers, so a departed rank can neither hold a barrier hostage
/// nor pin the SSP staleness window.  Each transition bumps the epoch.
///
/// Aggregates are maintained *incrementally* (DESIGN.md §10): a counting
/// multiset of live-worker clocks plus a live counter make `min_clock`/
/// `max_clock` O(log k), and `at_barrier`/`live_count` O(1), instead of
/// the O(k) scans the seed paid per gating query — the scans survive as
/// `debug_assert!` cross-checks, so every debug/test run still verifies
/// the incremental state against first principles.
#[derive(Debug, Clone)]
pub struct SyncState {
    mode: SyncMode,
    clocks: Vec<u64>,
    /// Global model version (number of applied updates).
    version: u64,
    /// Model version each worker last pulled.
    pulled: Vec<u64>,
    /// Current cluster membership; dead ranks are invisible to gating.
    live: Vec<bool>,
    /// Membership epoch: bumped on every retire/admit.
    epoch: u64,
    /// Live workers (incremental mirror of `live`).
    n_live: usize,
    /// clock value → number of live workers currently at it.  First key
    /// is `min_clock`, last is `max_clock`, `len() <= 1` is the barrier.
    clock_counts: std::collections::BTreeMap<u64, usize>,
}

impl SyncState {
    pub fn new(mode: SyncMode, k: usize) -> Self {
        Self::with_live(mode, &vec![true; k])
    }

    /// Start with an explicit membership (scheduled `join_at` workers
    /// begin absent).
    pub fn with_live(mode: SyncMode, live: &[bool]) -> Self {
        let n_live = live.iter().filter(|&&l| l).count();
        let mut clock_counts = std::collections::BTreeMap::new();
        if n_live > 0 {
            clock_counts.insert(0u64, n_live);
        }
        SyncState {
            mode,
            clocks: vec![0; live.len()],
            version: 0,
            pulled: vec![0; live.len()],
            live: live.to_vec(),
            epoch: 0,
            n_live,
            clock_counts,
        }
    }

    pub fn mode(&self) -> SyncMode {
        self.mode
    }

    pub fn clock(&self, worker: usize) -> u64 {
        self.clocks[worker]
    }

    pub fn is_live(&self, worker: usize) -> bool {
        self.live[worker]
    }

    pub fn live_count(&self) -> usize {
        debug_assert_eq!(
            self.n_live,
            self.live.iter().filter(|&&l| l).count(),
            "incremental live count diverged from the scan"
        );
        self.n_live
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Remove one live worker currently at clock `c` from the multiset.
    fn counts_remove(&mut self, c: u64) {
        match self.clock_counts.get_mut(&c) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                self.clock_counts.remove(&c);
            }
            None => debug_assert!(false, "clock {c} missing from the multiset"),
        }
    }

    /// Add one live worker at clock `c` to the multiset.
    fn counts_insert(&mut self, c: u64) {
        *self.clock_counts.entry(c).or_insert(0) += 1;
    }

    /// The seed's O(k) min-clock scan, kept as the debug cross-check.
    fn scan_min_clock(&self) -> u64 {
        self.clocks
            .iter()
            .zip(&self.live)
            .filter(|(_, &l)| l)
            .map(|(&c, _)| c)
            .min()
            .unwrap_or(0)
    }

    /// The seed's O(k) max-clock scan, kept as the debug cross-check.
    fn scan_max_clock(&self) -> u64 {
        self.clocks
            .iter()
            .zip(&self.live)
            .filter(|(_, &l)| l)
            .map(|(&c, _)| c)
            .max()
            .unwrap_or(0)
    }

    /// Min clock over *live* workers (0 when none are live).
    pub fn min_clock(&self) -> u64 {
        let m = self.clock_counts.keys().next().copied().unwrap_or(0);
        debug_assert_eq!(m, self.scan_min_clock(), "incremental min-clock diverged");
        m
    }

    /// Max clock over *live* workers (0 when none are live).
    pub fn max_clock(&self) -> u64 {
        let m = self.clock_counts.keys().next_back().copied().unwrap_or(0);
        debug_assert_eq!(m, self.scan_max_clock(), "incremental max-clock diverged");
        m
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// May `worker` start its next iteration?
    ///
    /// Dead workers never proceed.  BSP: only if nobody live is behind it
    /// (it will then wait at the barrier anyway — the engine models
    /// waiting; here we gate at one-iteration lockstep).  ASP: always.
    /// SSP: if it leads the slowest live worker by < bound.
    pub fn may_proceed(&self, worker: usize) -> bool {
        if !self.live[worker] {
            return false;
        }
        match self.mode {
            SyncMode::Bsp => self.clocks[worker] == self.min_clock(),
            SyncMode::Asp => true,
            SyncMode::Ssp { bound } => {
                self.clocks[worker] < self.min_clock() + bound + 1
            }
        }
    }

    /// Retire a live worker (spot revocation): it disappears from every
    /// gating aggregate; its clock freezes where it was.
    pub fn retire(&mut self, worker: usize) {
        assert!(self.live[worker], "retire of already-dead worker {worker}");
        self.counts_remove(self.clocks[worker]);
        self.n_live -= 1;
        self.live[worker] = false;
        self.epoch += 1;
    }

    /// Admit an absent worker (recovery / scheduled join).  Its clock is
    /// seeded to the current live minimum so BSP lockstep and the SSP
    /// bound hold immediately, and it is marked as having pulled the
    /// *current* model version (a rejoin starts from the global model,
    /// never from stale pre-revocation state).
    pub fn admit(&mut self, worker: usize) {
        assert!(!self.live[worker], "admit of already-live worker {worker}");
        if self.live_count() > 0 {
            self.clocks[worker] = self.min_clock();
        }
        self.pulled[worker] = self.version;
        self.live[worker] = true;
        self.counts_insert(self.clocks[worker]);
        self.n_live += 1;
        self.epoch += 1;
    }

    /// Close a BSP round *without* a final push: when a mid-round
    /// revocation leaves every surviving worker already at the barrier,
    /// the session applies the round's aggregate update and calls this
    /// for the version bump `push_update` would otherwise have done.
    pub fn close_round(&mut self) {
        debug_assert!(matches!(self.mode, SyncMode::Bsp));
        debug_assert!(self.at_barrier());
        self.version += 1;
    }

    /// Record that `worker` pulled the current model (starts an iteration).
    pub fn pull(&mut self, worker: usize) {
        self.pulled[worker] = self.version;
    }

    /// Record a completed iteration; returns the *staleness* of the
    /// worker's update: how many global updates landed since it pulled.
    ///
    /// Version accounting is mode-aware: ASP/SSP apply each worker's
    /// update individually (one version bump per push), while BSP
    /// applies ONE λ-aggregated update per global round — the version
    /// advances when the barrier closes.  Every BSP worker therefore
    /// pulled the model the round's single update is computed against,
    /// and BSP staleness is zero by construction (an invariant the
    /// property tests pin down).
    pub fn push_update(&mut self, worker: usize) -> u64 {
        let staleness = self.version - self.pulled[worker];
        if self.live[worker] {
            self.counts_remove(self.clocks[worker]);
            self.counts_insert(self.clocks[worker] + 1);
        }
        self.clocks[worker] += 1;
        match self.mode {
            SyncMode::Bsp => {
                if self.at_barrier() {
                    self.version += 1;
                }
            }
            SyncMode::Asp | SyncMode::Ssp { .. } => self.version += 1,
        }
        staleness
    }

    /// Checkpoint snapshot (DESIGN.md §15): mode + the irreducible
    /// state (`clocks`, `version`, `pulled`, `live`, `epoch`).  The
    /// incremental aggregates (`n_live`, `clock_counts`) are derived
    /// mirrors and are rebuilt on restore rather than persisted.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::ckpt::enc_u64;
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("mode", Json::Str(self.mode.label()));
        j.set(
            "clocks",
            Json::Arr(self.clocks.iter().map(|&c| enc_u64(c)).collect()),
        );
        j.set("version", enc_u64(self.version));
        j.set(
            "pulled",
            Json::Arr(self.pulled.iter().map(|&p| enc_u64(p)).collect()),
        );
        j.set("live", Json::Arr(self.live.iter().map(|&l| Json::Bool(l)).collect()));
        j.set("epoch", enc_u64(self.epoch));
        j
    }

    /// Rebuild from a [`SyncState::snapshot`], reconstructing the
    /// incremental aggregates from the persisted clocks + membership.
    pub fn restore(j: &crate::util::json::Json) -> Result<SyncState, String> {
        use crate::ckpt::dec_u64;
        let mode = j
            .get("mode")
            .as_str()
            .and_then(SyncMode::parse)
            .ok_or_else(|| format!("bad sync mode {:?}", j.get("mode")))?;
        let clocks: Vec<u64> = j
            .get("clocks")
            .as_arr()
            .ok_or("sync clocks missing")?
            .iter()
            .map(dec_u64)
            .collect::<Result<_, _>>()?;
        let pulled: Vec<u64> = j
            .get("pulled")
            .as_arr()
            .ok_or("sync pulled missing")?
            .iter()
            .map(dec_u64)
            .collect::<Result<_, _>>()?;
        let live: Vec<bool> = j
            .get("live")
            .as_arr()
            .ok_or("sync live missing")?
            .iter()
            .map(|b| b.as_bool().ok_or_else(|| format!("bad live flag {b:?}")))
            .collect::<Result<_, _>>()?;
        if clocks.len() != live.len() || pulled.len() != live.len() {
            return Err("sync vectors disagree on k".to_string());
        }
        let mut n_live = 0;
        let mut clock_counts = std::collections::BTreeMap::new();
        for (&c, &l) in clocks.iter().zip(&live) {
            if l {
                n_live += 1;
                *clock_counts.entry(c).or_insert(0) += 1;
            }
        }
        Ok(SyncState {
            mode,
            clocks,
            version: dec_u64(j.get("version"))?,
            pulled,
            live,
            epoch: dec_u64(j.get("epoch"))?,
            n_live,
            clock_counts,
        })
    }

    /// BSP full-barrier check: all *live* workers at the same clock.
    /// O(1): the clock multiset has at most one distinct key.
    pub fn at_barrier(&self) -> bool {
        let b = self.clock_counts.len() <= 1;
        debug_assert_eq!(
            b,
            self.scan_min_clock() == self.scan_max_clock(),
            "incremental barrier check diverged"
        );
        b
    }
}

/// Statistical-efficiency discount of a stale gradient.
///
/// The paper (§III-B) notes the staleness→slowdown relation is "not as
/// simple to model as the effect of stragglers on BSP, and is not
/// necessarily linear"; following the bounded-delay analyses it cites
/// ([18], [19]), we use a hyperbolic discount: a gradient with staleness
/// s contributes ≈ 1/(1+γ·s) of a fresh gradient's progress.
pub fn staleness_discount(staleness: u64, gamma: f64) -> f64 {
    1.0 / (1.0 + gamma * staleness as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_modes() {
        assert_eq!(SyncMode::parse("bsp"), Some(SyncMode::Bsp));
        assert_eq!(SyncMode::parse("asp"), Some(SyncMode::Asp));
        assert_eq!(SyncMode::parse("ssp:3"), Some(SyncMode::Ssp { bound: 3 }));
        assert_eq!(SyncMode::parse("nope"), None);
        assert_eq!(SyncMode::Ssp { bound: 2 }.label(), "ssp:2");
    }

    #[test]
    fn bsp_lockstep() {
        let mut s = SyncState::new(SyncMode::Bsp, 3);
        assert!(s.may_proceed(0) && s.may_proceed(1) && s.may_proceed(2));
        s.pull(0);
        s.push_update(0);
        // Worker 0 finished iter 0; it may not start iter 1 until others do.
        assert!(!s.may_proceed(0));
        assert!(s.may_proceed(1) && s.may_proceed(2));
        s.pull(1);
        s.push_update(1);
        s.pull(2);
        s.push_update(2);
        assert!(s.at_barrier());
        assert!(s.may_proceed(0));
    }

    #[test]
    fn asp_never_blocks_and_counts_staleness() {
        let mut s = SyncState::new(SyncMode::Asp, 2);
        s.pull(0);
        s.pull(1);
        assert_eq!(s.push_update(0), 0); // fresh
        assert!(s.may_proceed(1));
        // Worker 1 pulled before worker 0's update landed ⇒ staleness 1.
        assert_eq!(s.push_update(1), 1);
        // Fast worker loops 3 more times while 1 idles.
        for _ in 0..3 {
            s.pull(0);
            assert_eq!(s.push_update(0), 0);
        }
        s.pull(1);
        // No updates landed since pull ⇒ staleness 0 again.
        assert_eq!(s.push_update(1), 0);
        assert!(s.may_proceed(0));
    }

    #[test]
    fn ssp_bounds_lead() {
        let mut s = SyncState::new(SyncMode::Ssp { bound: 2 }, 2);
        // Worker 0 races ahead.
        for i in 0..3 {
            assert!(s.may_proceed(0), "iter {i}");
            s.pull(0);
            s.push_update(0);
        }
        // clock0=3, clock1=0, bound=2 ⇒ blocked now.
        assert!(!s.may_proceed(0));
        assert!(s.may_proceed(1));
        s.pull(1);
        s.push_update(1);
        assert!(s.may_proceed(0));
    }

    #[test]
    fn bsp_round_is_one_version_and_zero_staleness() {
        let mut s = SyncState::new(SyncMode::Bsp, 3);
        for round in 0..3u64 {
            for w in 0..3 {
                s.pull(w);
            }
            for w in 0..3 {
                assert_eq!(s.push_update(w), 0, "round {round} worker {w}");
            }
            // One aggregated update per barrier, not three.
            assert_eq!(s.version(), round + 1);
        }
    }

    #[test]
    fn retire_unblocks_bsp_barrier() {
        let mut s = SyncState::new(SyncMode::Bsp, 3);
        for w in 0..3 {
            s.pull(w);
        }
        s.push_update(0);
        s.push_update(1);
        // Worker 2 never finishes — it gets revoked instead.
        assert!(!s.at_barrier());
        s.retire(2);
        assert_eq!(s.epoch(), 1);
        assert_eq!(s.live_count(), 2);
        // Survivors are now all at clock 1: barrier holds without rank 2.
        assert!(s.at_barrier());
        assert!(!s.may_proceed(2), "dead worker must not proceed");
        s.close_round();
        assert_eq!(s.version(), 1);
        assert!(s.may_proceed(0) && s.may_proceed(1));
    }

    #[test]
    fn retire_unpins_ssp_staleness_window() {
        let mut s = SyncState::new(SyncMode::Ssp { bound: 1 }, 2);
        s.pull(0);
        s.push_update(0);
        s.pull(0);
        s.push_update(0);
        // clock0=2, clock1=0, bound=1 ⇒ worker 0 is blocked on the laggard.
        assert!(!s.may_proceed(0));
        s.retire(1);
        // min over live is now worker 0 itself ⇒ unblocked.
        assert!(s.may_proceed(0));
    }

    #[test]
    fn admit_seeds_clock_and_version() {
        let mut s = SyncState::new(SyncMode::Bsp, 3);
        s.retire(1);
        for _ in 0..2 {
            for w in [0usize, 2] {
                s.pull(w);
            }
            for w in [0usize, 2] {
                s.push_update(w);
            }
        }
        assert_eq!(s.version(), 2);
        assert_eq!(s.min_clock(), 2);
        s.admit(1);
        assert_eq!(s.epoch(), 2);
        // Seeded at the live minimum and at the current model version:
        // lockstep resumes with zero staleness for the rejoiner.
        assert_eq!(s.clock(1), 2);
        assert!(s.may_proceed(1));
        s.pull(1);
        assert_eq!(s.push_update(1), 0);
    }

    #[test]
    fn initial_membership_can_start_absent() {
        let s = SyncState::with_live(SyncMode::Bsp, &[true, false, true]);
        assert_eq!(s.live_count(), 2);
        assert!(!s.may_proceed(1));
        assert!(s.may_proceed(0) && s.may_proceed(2));
        assert_eq!(s.epoch(), 0);
    }

    #[test]
    fn incremental_aggregates_track_churned_clocks() {
        // Drive an SSP gate through uneven progress + churn; every query
        // also runs the debug_assert scan cross-checks internally.
        let mut s = SyncState::new(SyncMode::Ssp { bound: 3 }, 4);
        for _ in 0..3 {
            s.pull(0);
            s.push_update(0);
        }
        s.pull(1);
        s.push_update(1);
        assert_eq!((s.min_clock(), s.max_clock()), (0, 3));
        assert!(!s.at_barrier());
        // Retiring the laggards advances the live minimum.
        s.retire(2);
        s.retire(3);
        assert_eq!((s.min_clock(), s.max_clock()), (1, 3));
        assert_eq!(s.live_count(), 2);
        // Admission seeds at the live minimum: multiset gains a worker
        // at clock 1.
        s.admit(2);
        assert_eq!(s.clock(2), 1);
        assert_eq!((s.min_clock(), s.max_clock()), (1, 3));
        assert_eq!(s.live_count(), 3);
        // Catch everyone up to clock 3: barrier collapses to one key.
        for _ in 0..2 {
            for w in [1usize, 2] {
                s.pull(w);
                s.push_update(w);
            }
        }
        assert!(s.at_barrier());
        assert_eq!((s.min_clock(), s.max_clock()), (3, 3));
    }

    #[test]
    fn all_revoked_aggregates_read_zero() {
        let mut s = SyncState::new(SyncMode::Asp, 2);
        s.pull(0);
        s.push_update(0);
        s.retire(0);
        s.retire(1);
        assert_eq!(s.live_count(), 0);
        assert_eq!((s.min_clock(), s.max_clock()), (0, 0));
        assert!(s.at_barrier());
        // Sole survivor re-admitted: its frozen clock is the new band.
        s.admit(0);
        assert_eq!((s.min_clock(), s.max_clock()), (1, 1));
    }

    #[test]
    fn snapshot_round_trip_preserves_gating() {
        let mut s = SyncState::new(SyncMode::Ssp { bound: 2 }, 4);
        for _ in 0..3 {
            s.pull(0);
            s.push_update(0);
        }
        s.pull(1);
        s.push_update(1);
        s.retire(3);
        let j = crate::util::json::Json::parse(&s.snapshot().to_string()).unwrap();
        let r = SyncState::restore(&j).unwrap();
        assert_eq!(r.mode(), s.mode());
        assert_eq!(r.version(), s.version());
        assert_eq!(r.epoch(), s.epoch());
        assert_eq!(r.live_count(), s.live_count());
        assert_eq!((r.min_clock(), r.max_clock()), (s.min_clock(), s.max_clock()));
        for w in 0..4 {
            assert_eq!(r.clock(w), s.clock(w));
            assert_eq!(r.is_live(w), s.is_live(w));
            assert_eq!(r.may_proceed(w), s.may_proceed(w));
        }
        assert_eq!(r.at_barrier(), s.at_barrier());
    }

    #[test]
    fn discount_shape() {
        assert_eq!(staleness_discount(0, 0.5), 1.0);
        assert!((staleness_discount(1, 0.5) - 1.0 / 1.5).abs() < 1e-12);
        assert!(staleness_discount(10, 0.5) < staleness_discount(2, 0.5));
        // γ=0 disables the penalty.
        assert_eq!(staleness_discount(100, 0.0), 1.0);
    }
}
