//! The pluggable batch-policy seam (DESIGN.md §14).
//!
//! [`BatchPolicy`] is exactly the surface the Session event loop
//! consumes: feed iteration-time observations, ask for an adjustment,
//! and drive membership (`retire`/`admit`) plus the bucket-quantization
//! round-trip (`set_batches`).  [`super::DynamicBatcher`] — the paper's
//! Eq. 2/4 proportional controller — is the reference implementation;
//! this module adds [`OptimalBatcher`], the throughput-model one-shot
//! allocator (Nie et al., PAPERS.md): it fits a per-worker linear
//! iteration-time model t_k(b) = a_k·b + c_k from the observations and
//! jumps straight to the time-equalizing allocation instead of
//! iterating proportional corrections.

use super::{water_fill, Adjustment, ControllerCfg, DynamicBatcher};

/// What the Session calls on a batch controller.  Implementations must
/// conserve Σb over the live cohort across adjustments *and* membership
/// transitions — the λ-weighted aggregation (Eq. 2) depends on it.
pub trait BatchPolicy {
    /// Feed one iteration-time observation for live worker `k`.
    fn observe(&mut self, k: usize, iter_time: f64);

    /// Run one control step; [`Adjustment::Apply`] carries the new
    /// full-length batch vector (retired ranks at 0).
    fn maybe_adjust(&mut self) -> Adjustment;

    /// Remove worker `k` (revocation); its mass moves to the survivors.
    fn retire(&mut self, k: usize);

    /// (Re-)admit worker `k` with a warm-start batch.
    fn admit(&mut self, k: usize);

    /// Force-set batches (bucket quantization round-trips through this).
    fn set_batches(&mut self, batches: &[f64]);

    /// Current full-length batch vector into a caller-owned buffer.
    fn batches_into(&self, out: &mut Vec<f64>);

    /// λ_k = b_k / Σb into a caller-owned buffer.
    fn lambdas_into(&self, out: &mut Vec<f64>);

    /// Smoothed iteration-time estimate for worker `k` (the failure
    /// detector's deadline input; None until observed).
    fn smoothed_iter_time(&self, k: usize) -> Option<f64>;

    /// Σb over the live cohort (invariant).
    fn global_batch(&self) -> f64;

    /// Adjustments applied so far.
    fn adjustments(&self) -> usize;

    /// Short policy name for logs/labels.
    fn label(&self) -> &'static str;

    /// Checkpoint snapshot of the policy's full mutable state
    /// (DESIGN.md §15).  Restore goes through the concrete type's
    /// `restore` constructor, keyed on [`BatchPolicy::label`].
    fn snapshot(&self) -> crate::util::json::Json;
}

impl BatchPolicy for DynamicBatcher {
    fn observe(&mut self, k: usize, iter_time: f64) {
        DynamicBatcher::observe(self, k, iter_time);
    }
    fn maybe_adjust(&mut self) -> Adjustment {
        DynamicBatcher::maybe_adjust(self)
    }
    fn retire(&mut self, k: usize) {
        DynamicBatcher::retire(self, k);
    }
    fn admit(&mut self, k: usize) {
        DynamicBatcher::admit(self, k);
    }
    fn set_batches(&mut self, batches: &[f64]) {
        DynamicBatcher::set_batches(self, batches);
    }
    fn batches_into(&self, out: &mut Vec<f64>) {
        DynamicBatcher::batches_into(self, out);
    }
    fn lambdas_into(&self, out: &mut Vec<f64>) {
        DynamicBatcher::lambdas_into(self, out);
    }
    fn smoothed_iter_time(&self, k: usize) -> Option<f64> {
        DynamicBatcher::smoothed_iter_time(self, k)
    }
    fn global_batch(&self) -> f64 {
        DynamicBatcher::global_batch(self)
    }
    fn adjustments(&self) -> usize {
        DynamicBatcher::adjustments(self)
    }
    fn label(&self) -> &'static str {
        "dynamic"
    }
    fn snapshot(&self) -> crate::util::json::Json {
        DynamicBatcher::snapshot(self)
    }
}

/// Per-worker running least squares over (batch, iteration-time) pairs.
///
/// While every observation shares one batch size the model degenerates
/// to the through-origin fit a_k = t̄_k/b_k, c_k = 0 — exactly the
/// FLOPs-proportional assumption, so the *first* one-shot jump equals
/// the throughput-proportional allocation computed from measured (not
/// estimated) speeds.  Once two distinct batch sizes have been observed
/// the full affine fit kicks in and the second jump absorbs the fixed
/// per-iteration overhead c_k the proportional law cannot see.
#[derive(Debug, Clone, Default)]
struct LinFit {
    n: f64,
    sum_b: f64,
    sum_t: f64,
    sum_bb: f64,
    sum_bt: f64,
    /// Observations in the current control interval (gates the jump).
    interval: usize,
}

impl LinFit {
    fn push(&mut self, b: f64, t: f64) {
        self.n += 1.0;
        self.sum_b += b;
        self.sum_t += t;
        self.sum_bb += b * b;
        self.sum_bt += b * t;
        self.interval += 1;
    }

    fn clear(&mut self) {
        *self = LinFit::default();
    }

    /// (a, c) of t(b) = a·b + c.  Falls back to the through-origin
    /// slope when the batch column has no spread or the affine slope
    /// comes out non-positive (pure noise); None until any observation.
    fn model(&self) -> Option<(f64, f64)> {
        if self.n < 1.0 || self.sum_b <= 0.0 {
            return None;
        }
        let denom = self.n * self.sum_bb - self.sum_b * self.sum_b;
        if denom > 1e-9 * self.sum_bb.max(1.0) {
            let a = (self.n * self.sum_bt - self.sum_b * self.sum_t) / denom;
            let c = (self.sum_t - a * self.sum_b) / self.n;
            if a > 0.0 {
                return Some((a, c.max(0.0)));
            }
        }
        Some((self.sum_t / self.sum_b, 0.0))
    }

    fn snapshot(&self) -> crate::util::json::Json {
        use crate::ckpt::enc_f64;
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("n", enc_f64(self.n));
        j.set("sum_b", enc_f64(self.sum_b));
        j.set("sum_t", enc_f64(self.sum_t));
        j.set("sum_bb", enc_f64(self.sum_bb));
        j.set("sum_bt", enc_f64(self.sum_bt));
        j.set("interval", Json::Num(self.interval as f64));
        j
    }

    fn restore(j: &crate::util::json::Json) -> Result<LinFit, String> {
        use crate::ckpt::{dec_f64, dec_usize};
        Ok(LinFit {
            n: dec_f64(j.get("n"))?,
            sum_b: dec_f64(j.get("sum_b"))?,
            sum_t: dec_f64(j.get("sum_t"))?,
            sum_bb: dec_f64(j.get("sum_bb"))?,
            sum_bt: dec_f64(j.get("sum_bt"))?,
            interval: dec_usize(j.get("interval"))?,
        })
    }
}

/// One-shot optimal allocator (Nie et al., PAPERS.md; DESIGN.md §14).
///
/// Wraps a [`DynamicBatcher`] for the shared bookkeeping — membership
/// water-filling, warm starts, smoothed estimates for the failure
/// detector — but replaces the proportional control law: after
/// `min_obs` observations per live worker it solves for the allocation
/// that *equalizes modeled iteration times*,
///
/// ```text
/// t_k(b_k) = a_k·b_k + c_k = τ   with   Σ b_k = B
/// ⇒  τ = (B + Σ c_k/a_k) / Σ 1/a_k,   b_k = (τ − c_k)/a_k
/// ```
///
/// water-filled into [b_min, b_max], in a single adjustment.  The jump
/// re-arms on membership epochs and capacity-regime drifts (which also
/// invalidate the fitted models); within the dead-band it goes quiet.
#[derive(Debug, Clone)]
pub struct OptimalBatcher {
    inner: DynamicBatcher,
    fits: Vec<LinFit>,
    adjustments: usize,
}

impl OptimalBatcher {
    pub fn new(cfg: ControllerCfg, initial: &[f64]) -> Self {
        let live = vec![true; initial.len()];
        Self::try_with_membership(cfg, initial, &live).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_with_membership(
        cfg: ControllerCfg,
        initial: &[f64],
        live: &[bool],
    ) -> Result<Self, String> {
        let inner = DynamicBatcher::try_with_membership(cfg, initial, live)?;
        let fits = vec![LinFit::default(); initial.len()];
        Ok(OptimalBatcher {
            inner,
            fits,
            adjustments: 0,
        })
    }

    /// Restart every worker's control interval (the fit history is
    /// kept: the per-worker speed model survives an allocation change —
    /// more distinct batch sizes only sharpen it).
    fn reset_intervals(&mut self) {
        for f in &mut self.fits {
            f.interval = 0;
        }
    }

    /// Rebuild from a [`BatchPolicy::snapshot`] taken on this type.
    pub fn restore(
        cfg: ControllerCfg,
        j: &crate::util::json::Json,
    ) -> Result<OptimalBatcher, String> {
        use crate::ckpt::dec_usize;
        let inner = DynamicBatcher::restore(cfg, j.get("inner"))?;
        let fits = j
            .get("fits")
            .as_arr()
            .ok_or("optimal snapshot has no fits array")?
            .iter()
            .map(LinFit::restore)
            .collect::<Result<Vec<_>, _>>()?;
        if fits.len() != inner.k() {
            return Err(format!(
                "optimal snapshot: {} fits for {} workers",
                fits.len(),
                inner.k()
            ));
        }
        Ok(OptimalBatcher {
            inner,
            fits,
            adjustments: dec_usize(j.get("adjustments"))?,
        })
    }
}

impl BatchPolicy for OptimalBatcher {
    fn observe(&mut self, k: usize, iter_time: f64) {
        self.fits[k].push(self.inner.batch(k), iter_time);
        self.inner.observe(k, iter_time);
    }

    fn maybe_adjust(&mut self) -> Adjustment {
        // A capacity-regime drift invalidates the fitted models: the
        // (b, t) pairs describe the old regime's speeds.
        if self.inner.take_drifted() {
            for (i, f) in self.fits.iter_mut().enumerate() {
                if self.inner.is_active(i) {
                    f.clear();
                }
            }
            return Adjustment::Hold;
        }
        let k = self.inner.k();
        let active: Vec<usize> = (0..k).filter(|&i| self.inner.is_active(i)).collect();
        if active.len() < 2 {
            return Adjustment::Hold;
        }
        let (min_obs, deadband, b_min, b_max) = {
            let cfg = self.inner.cfg();
            (cfg.min_obs.max(1), cfg.deadband, cfg.b_min, cfg.b_max)
        };
        if active.iter().any(|&i| self.fits[i].interval < min_obs) {
            return Adjustment::Hold;
        }
        let models: Vec<(f64, f64)> = match active
            .iter()
            .map(|&i| self.fits[i].model())
            .collect::<Option<Vec<_>>>()
        {
            Some(m) => m,
            None => return Adjustment::Hold,
        };
        // Equalize modeled iteration times at constant Σb.
        let target = self.inner.global_batch();
        let inv_a: f64 = models.iter().map(|&(a, _)| 1.0 / a).sum();
        let c_over_a: f64 = models.iter().map(|&(a, c)| c / a).sum();
        let tau = (target + c_over_a) / inv_a;
        let mut proposal: Vec<f64> = models
            .iter()
            .map(|&(a, c)| (((tau - c) / a).max(b_min)).min(b_max))
            .collect();
        let bmaxes = vec![b_max; proposal.len()];
        water_fill(&mut proposal, target, b_min, &bmaxes);

        // Dead-band: already equalized (to model accuracy) — go quiet.
        let max_rel = active
            .iter()
            .zip(&proposal)
            .map(|(&i, &p)| {
                let b = self.inner.batch(i);
                ((p - b) / b).abs()
            })
            .fold(0.0, f64::max);
        self.reset_intervals();
        if max_rel <= deadband {
            return Adjustment::Hold;
        }
        let mut full = vec![0.0; k];
        for (&i, &p) in active.iter().zip(&proposal) {
            full[i] = p;
        }
        // Mirrors DynamicBatcher's apply step: record the new batches
        // (clamped + smoothing intervals reset) inside the controller.
        self.inner.set_batches(&full);
        self.adjustments += 1;
        Adjustment::Apply(full)
    }

    fn retire(&mut self, k: usize) {
        self.inner.retire(k);
        // The instance is gone; a future admission at this rank may be
        // a different machine (autoscaled replacement).
        self.fits[k].clear();
        self.reset_intervals();
    }

    fn admit(&mut self, k: usize) {
        self.inner.admit(k);
        self.fits[k].clear();
        self.reset_intervals();
    }

    fn set_batches(&mut self, batches: &[f64]) {
        self.inner.set_batches(batches);
        self.reset_intervals();
    }

    fn batches_into(&self, out: &mut Vec<f64>) {
        self.inner.batches_into(out);
    }

    fn lambdas_into(&self, out: &mut Vec<f64>) {
        self.inner.lambdas_into(out);
    }

    fn smoothed_iter_time(&self, k: usize) -> Option<f64> {
        self.inner.smoothed_iter_time(k)
    }

    fn global_batch(&self) -> f64 {
        self.inner.global_batch()
    }

    fn adjustments(&self) -> usize {
        self.adjustments
    }

    fn label(&self) -> &'static str {
        "optimal"
    }

    fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("inner", self.inner.snapshot());
        j.set(
            "fits",
            Json::Arr(self.fits.iter().map(|f| f.snapshot()).collect()),
        );
        j.set("adjustments", Json::Num(self.adjustments as f64));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linear workers t_k(b) = b / x_k: the classic 1x/2x/4x static
    /// heterogeneity.  The one-shot policy must reach the dead-band
    /// steady state in ≤ 2 adjustments (ISSUE 8 acceptance: the PID
    /// needs ≥ 2 for the same split).
    #[test]
    fn one_shot_reaches_steady_state_in_at_most_two_adjustments() {
        let xs = [10.0, 20.0, 40.0];
        let cfg = ControllerCfg {
            min_obs: 1,
            backoff: false,
            ..ControllerCfg::default()
        };
        let mut ctl = OptimalBatcher::new(cfg, &[64.0, 64.0, 64.0]);
        let mut b = Vec::new();
        for _ in 0..40 {
            ctl.batches_into(&mut b);
            for (k, &x) in xs.iter().enumerate() {
                ctl.observe(k, b[k] / x);
            }
            ctl.maybe_adjust();
        }
        assert!(
            ctl.adjustments() <= 2,
            "one-shot took {} adjustments",
            ctl.adjustments()
        );
        // Steady state = throughput-proportional split of Σb = 192.
        ctl.batches_into(&mut b);
        let expect = [192.0 * 10.0 / 70.0, 192.0 * 20.0 / 70.0, 192.0 * 40.0 / 70.0];
        for (got, want) in b.iter().zip(expect) {
            assert!(
                (got - want).abs() / want < 0.05,
                "batches {b:?} != {expect:?}"
            );
        }
        let sum: f64 = b.iter().sum();
        assert!((sum - 192.0).abs() < 1e-6);
    }

    /// With a fixed per-iteration overhead the equalizing allocation is
    /// NOT FLOPs-proportional — the affine fit must find it once two
    /// distinct batch sizes per worker have been seen.
    #[test]
    fn affine_fit_beats_proportional_on_fixed_overhead() {
        // t_k(b) = b/x_k + c: equal c, speeds 1x/3x.
        let xs = [10.0, 30.0];
        let c = 2.0;
        let cfg = ControllerCfg {
            min_obs: 2,
            backoff: false,
            deadband: 0.02,
            ..ControllerCfg::default()
        };
        let mut ctl = OptimalBatcher::new(cfg, &[60.0, 60.0]);
        let mut b = Vec::new();
        for _ in 0..30 {
            ctl.batches_into(&mut b);
            for (k, &x) in xs.iter().enumerate() {
                ctl.observe(k, b[k] / x + c);
            }
            ctl.maybe_adjust();
        }
        ctl.batches_into(&mut b);
        // Equalize b1/10 + 2 = b2/30 + 2 with b1+b2 = 120 ⇒ 30/90.
        assert!((b[0] - 30.0).abs() < 2.0, "batches {b:?}");
        assert!((b[1] - 90.0).abs() < 2.0, "batches {b:?}");
        let t0 = b[0] / 10.0 + c;
        let t1 = b[1] / 30.0 + c;
        assert!((t0 / t1 - 1.0).abs() < 0.05, "times not equalized: {t0} vs {t1}");
    }

    #[test]
    fn conserves_mass_across_membership_churn() {
        let cfg = ControllerCfg {
            min_obs: 1,
            ..ControllerCfg::default()
        };
        let mut ctl = OptimalBatcher::new(cfg, &[32.0, 32.0, 32.0, 32.0]);
        let total = ctl.global_batch();
        let xs = [5.0, 10.0, 20.0, 40.0];
        let mut b = Vec::new();
        for round in 0..30 {
            if round == 7 {
                BatchPolicy::retire(&mut ctl, 2);
            }
            if round == 15 {
                BatchPolicy::admit(&mut ctl, 2);
            }
            ctl.batches_into(&mut b);
            for (k, &x) in xs.iter().enumerate() {
                if b[k] > 0.0 {
                    ctl.observe(k, b[k] / x);
                }
            }
            ctl.maybe_adjust();
            ctl.batches_into(&mut b);
            let sum: f64 = b.iter().sum();
            assert!(
                (sum - total).abs() < 1e-6 * total,
                "round {round}: Σb {sum} != {total}"
            );
        }
    }

    #[test]
    fn through_origin_fallback_on_single_batch_size() {
        let mut f = LinFit::default();
        f.push(64.0, 6.4);
        f.push(64.0, 6.4);
        let (a, c) = f.model().unwrap();
        assert!((a - 0.1).abs() < 1e-12);
        assert_eq!(c, 0.0);
    }

    #[test]
    fn affine_fit_recovers_slope_and_intercept() {
        let mut f = LinFit::default();
        for b in [32.0, 64.0, 128.0] {
            f.push(b, 0.05 * b + 1.5);
        }
        let (a, c) = f.model().unwrap();
        assert!((a - 0.05).abs() < 1e-9);
        assert!((c - 1.5).abs() < 1e-9);
    }
}
