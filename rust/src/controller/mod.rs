//! The paper's contribution: mini-batch allocation policies (§III).
//!
//! - [`uniform_alloc`]: vanilla TF baseline — every worker gets b0.
//! - [`static_alloc`]: open-loop variable batching, b_k ∝ FLOPs (§III-B).
//! - [`DynamicBatcher`]: the closed-loop proportional controller (§III-C)
//!   with EWMA error smoothing, dead-banding, batch-size bounds with
//!   adaptive b_max shrink, and global-batch conservation.
//!
//! Control law (per worker k, smoothed iteration time μ_k, mean t̄):
//!
//! ```text
//! τ_k  = μ_k − t̄                  error
//! X_k  = b_k / μ_k                 empirical throughput
//! Δb_k = −X_k · τ_k                Eq. 4   ⇒   b_k' = b_k · t̄ / μ_k
//! ```
//!
//! followed by renormalization to conserve Σ b_k = K·b0, clamping to
//! [b_min, b_max_k], and a dead-band: apply only if some worker moves by
//! more than `deadband` relative (default 5%, matching the paper's
//! TF kill-restart overhead calculus).
//!
//! The Session consumes controllers through the [`BatchPolicy`] trait
//! (DESIGN.md §14): [`DynamicBatcher`] is the reference implementation,
//! [`OptimalBatcher`] the one-shot model-based allocator (Nie et al.),
//! and [`RlBatcher`] the tabular bandit policy (DYNAMIX).

pub mod bucket;
pub mod policy;
pub mod rl;

pub use policy::{BatchPolicy, OptimalBatcher};
pub use rl::{RlBatcher, RlTable};

use crate::util::stats::Ewma;

/// Uniform batching baseline: every worker processes b0.
pub fn uniform_alloc(b0: f64, k: usize) -> Vec<f64> {
    vec![b0; k]
}

/// Open-loop variable batching (§III-B): b_k = K·b0·X_k / ΣX_i with X the
/// *estimated* throughput (FLOPs or core counts). Conserves Σb = K·b0.
pub fn static_alloc(b0: f64, estimates: &[f64]) -> Vec<f64> {
    assert!(!estimates.is_empty());
    assert!(estimates.iter().all(|&x| x > 0.0), "estimates must be > 0");
    let total: f64 = estimates.iter().sum();
    let k = estimates.len() as f64;
    estimates.iter().map(|&x| k * b0 * x / total).collect()
}

/// [`static_alloc`] against explicit controller bounds: skewed estimates
/// (FLOPs ratios beyond b_max/b0) used to emit batches outside
/// [b_min, b_max] and panic `DynamicBatcher::with_membership`'s bounds
/// assert; this variant water-fills the proposal back into the box and
/// returns a validated error when the mass itself is infeasible.
///
/// The water-fill runs *only* when some batch actually violates a bound:
/// rescaling an in-bounds proposal by Σ/Σ ≈ 1±ε would shift every batch
/// by an ulp and break bitwise reproducibility of committed goldens.
pub fn static_alloc_bounded(
    b0: f64,
    estimates: &[f64],
    b_min: f64,
    b_max: f64,
) -> Result<Vec<f64>, String> {
    if estimates.is_empty() {
        return Err("static allocation over an empty cohort".into());
    }
    if let Some(bad) = estimates.iter().find(|&&x| !(x > 0.0)) {
        return Err(format!("throughput estimate {bad} must be > 0"));
    }
    let k = estimates.len() as f64;
    let mass = k * b0;
    if mass < k * b_min - 1e-9 {
        return Err(format!(
            "global batch {mass} cannot give {k} workers b_min {b_min} each"
        ));
    }
    if mass > k * b_max + 1e-9 {
        return Err(format!(
            "global batch {mass} exceeds {k} workers at b_max {b_max}"
        ));
    }
    let mut alloc = static_alloc(b0, estimates);
    if alloc.iter().any(|&b| b < b_min || b > b_max) {
        let bmaxes = vec![b_max; estimates.len()];
        water_fill(&mut alloc, mass, b_min, &bmaxes);
    }
    Ok(alloc)
}

/// Configuration for the dynamic controller.
#[derive(Debug, Clone)]
pub struct ControllerCfg {
    /// Relative dead-band Δ_min(b): skip adjustment unless some worker's
    /// batch would change by more than this fraction (paper: 0.05).
    pub deadband: f64,
    /// Iteration-time smoothing weight. The paper smooths over *all*
    /// iterations since the previous readjustment; `0.0` selects that
    /// cumulative mean (EWMA's α→0 limit, variance ∝ 1/n — the reason the
    /// controller goes quiet in steady state instead of chasing noise).
    /// A value in (0, 1] selects a fixed-α EWMA instead.
    pub ewma_alpha: f64,
    /// Minimum samples since last adjustment before acting again.
    pub min_obs: usize,
    /// Global lower bound on any worker's batch.
    pub b_min: f64,
    /// Global upper bound on any worker's batch.
    pub b_max: f64,
    /// Shrink a worker's personal b_max when raising its batch lowered
    /// its throughput (Fig. 5 knee discovery).
    pub adaptive_bmax: bool,
    /// Renormalize so Σ b_k stays K·b0.
    pub conserve_global: bool,
    /// Adjustment backoff (engineering addition, DESIGN.md §5): after an
    /// adjustment whose largest move was *small* (< 4× deadband — i.e.
    /// chasing residual noise), double the observations required before
    /// the next one, capped at `backoff_cap × min_obs`. A *large* move
    /// (regime change: interference, preemption) resets the backoff so
    /// the controller stays responsive. Bounds total readjustment cost
    /// logarithmically on workloads whose iteration times respond weakly
    /// to batch size (comm-bound, e.g. MNIST/LR).
    pub backoff: bool,
    /// Max backoff multiplier over min_obs.
    pub backoff_cap: usize,
    /// Regime-change detection: if a fast EWMA of recent iteration times
    /// deviates from the cumulative interval mean by more than this
    /// relative fraction, the smoothing window resets so the controller
    /// reacts to interference/preemption in a few iterations instead of
    /// averaging the new regime away (0 disables).
    pub drift_reset: f64,
}

impl Default for ControllerCfg {
    fn default() -> Self {
        ControllerCfg {
            deadband: 0.05,
            ewma_alpha: 0.0,
            min_obs: 5,
            b_min: 1.0,
            b_max: 4096.0,
            adaptive_bmax: true,
            conserve_global: true,
            backoff: true,
            backoff_cap: 64,
            drift_reset: 0.15,
        }
    }
}

/// Interval smoother: cumulative mean (α = 0, the paper's
/// since-last-readjustment average) or fixed-α EWMA, plus a fast EWMA
/// used for regime-change (drift) detection.
#[derive(Debug, Clone)]
struct Smoother {
    alpha: f64,
    ewma: Ewma,
    sum: f64,
    n: usize,
    /// Ring of the last 5 samples; drift detection uses their median so
    /// a 1–2 sample impulse (one straggling iteration, a preemption
    /// spike) cannot trigger a reset — only a *sustained* level shift.
    recent: [f64; 5],
    recent_n: usize,
    drift_reset: f64,
    drifted: bool,
}

impl Smoother {
    fn new(alpha: f64, drift_reset: f64) -> Self {
        Smoother {
            alpha,
            ewma: Ewma::new(alpha.clamp(0.0, 1.0).max(f64::MIN_POSITIVE)),
            sum: 0.0,
            n: 0,
            recent: [0.0; 5],
            recent_n: 0,
            drift_reset,
            drifted: false,
        }
    }

    /// Median of the last 5 samples (None until 5 seen).
    fn recent_median(&self) -> Option<f64> {
        if self.recent_n < 5 {
            return None;
        }
        let mut v = self.recent;
        v.sort_by(f64::total_cmp);
        Some(v[2])
    }

    fn push(&mut self, x: f64) {
        self.n += 1;
        self.recent[(self.n - 1) % 5] = x;
        self.recent_n = (self.recent_n + 1).min(5);
        if self.alpha > 0.0 {
            self.ewma.push(x);
        } else {
            self.sum += x;
        }
        // Regime change: the *median* recent level left the interval
        // mean's band — restart the window seeded at the new level so μ
        // tracks the new regime within a few samples. (Median-of-5 makes
        // this robust to single-iteration impulses.)
        if self.drift_reset > 0.0 && self.n >= 8 {
            let long = self.get().unwrap();
            if let Some(med) = self.recent_median() {
                if (med / long - 1.0).abs() > self.drift_reset {
                    // Seed both smoothing modes as if the new regime had
                    // already produced DRIFT_SEED_N observations at the
                    // median level, so the post-drift warm-start weight
                    // is the same whichever estimator is active: the
                    // cumulative mean restarts at n = 3, sum = 3·med,
                    // and the EWMA absorbs the same 3 pseudo-samples
                    // (the first is a passthrough, so its value is med
                    // either way — what the extra pushes equalize is the
                    // seeded history both modes claim to have seen).
                    self.reset();
                    self.n = DRIFT_SEED_N;
                    self.recent_n = 0;
                    self.sum = med * DRIFT_SEED_N as f64;
                    for _ in 0..DRIFT_SEED_N {
                        self.ewma.push(med);
                    }
                    self.drifted = true;
                }
            }
        }
    }

    /// True once a drift reset happened since the last `take_drifted`.
    fn take_drifted(&mut self) -> bool {
        std::mem::take(&mut self.drifted)
    }

    fn get(&self) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        if self.alpha > 0.0 {
            self.ewma.get()
        } else {
            Some(self.sum / self.n as f64)
        }
    }

    fn count(&self) -> usize {
        self.n
    }

    /// True while the counter still includes drift-reset pseudo-samples.
    #[cfg(test)]
    fn seeded(&self) -> bool {
        self.n == DRIFT_SEED_N && self.recent_n == 0
    }

    fn reset(&mut self) {
        self.ewma.reset();
        self.recent_n = 0;
        self.sum = 0.0;
        self.n = 0;
    }

    /// Checkpoint snapshot: everything but the config knobs (`alpha`,
    /// `drift_reset`), which the restorer re-derives from the run
    /// config.  The 5-sample ring is persisted in place so the
    /// `(n - 1) % 5` write cursor lands exactly where it would have.
    fn snapshot(&self) -> crate::util::json::Json {
        use crate::ckpt::{enc_f64, enc_f64_slice, enc_opt_f64};
        use crate::util::json::Json;
        let (ev, ec) = self.ewma.state();
        let mut j = Json::obj();
        j.set("ewma_value", enc_opt_f64(ev));
        j.set("ewma_count", Json::Num(ec as f64));
        j.set("sum", enc_f64(self.sum));
        j.set("n", Json::Num(self.n as f64));
        j.set("recent", enc_f64_slice(&self.recent));
        j.set("recent_n", Json::Num(self.recent_n as f64));
        j.set("drifted", Json::Bool(self.drifted));
        j
    }

    /// Rebuild from [`Smoother::snapshot`] under the given config knobs.
    fn restore(
        alpha: f64,
        drift_reset: f64,
        j: &crate::util::json::Json,
    ) -> Result<Smoother, String> {
        use crate::ckpt::{dec_f64, dec_f64_vec, dec_opt_f64, dec_usize};
        let mut s = Smoother::new(alpha, drift_reset);
        let (ev, ec) = (
            dec_opt_f64(j.get("ewma_value"))?,
            dec_usize(j.get("ewma_count"))?,
        );
        s.ewma.set_state(ev, ec);
        s.sum = dec_f64(j.get("sum"))?;
        s.n = dec_usize(j.get("n"))?;
        let recent = dec_f64_vec(j.get("recent"))?;
        if recent.len() != 5 {
            return Err(format!("smoother ring has {} entries, want 5", recent.len()));
        }
        s.recent.copy_from_slice(&recent);
        s.recent_n = dec_usize(j.get("recent_n"))?;
        s.drifted = j
            .get("drifted")
            .as_bool()
            .ok_or("smoother drifted flag missing")?;
        Ok(s)
    }
}

/// Per-worker controller state.
#[derive(Debug, Clone)]
struct WorkerState {
    batch: f64,
    ewma: Smoother,
    /// Personal upper bound (starts at cfg.b_max, shrinks adaptively).
    b_max: f64,
    /// (batch, throughput) at the last adjustment, for knee detection.
    /// Survives retirement — it doubles as the warm-start throughput
    /// estimate when the worker is later re-admitted.
    last_point: Option<(f64, f64)>,
    /// Adjustments since the knee cap was set (cap expires at KNEE_TTL —
    /// periodic re-probing, so a stale cap from a transient capacity dip
    /// cannot strangle the worker forever; a true memory knee is simply
    /// re-detected one adjustment after each expiry).
    cap_age: usize,
    /// Membership: retired (spot-revoked) workers hold no batch mass and
    /// are invisible to the control law until re-admitted.
    active: bool,
}

impl WorkerState {
    /// Best available throughput estimate: the live smoothed one if the
    /// current interval has observations, else the estimate memorized at
    /// the last adjustment.
    fn throughput_estimate(&self) -> Option<f64> {
        self.ewma
            .get()
            .filter(|_| self.batch > 0.0)
            .map(|mu| self.batch / mu)
            .or(self.last_point.map(|(_, x)| x))
    }
}

/// Outcome of an adjustment attempt.
#[derive(Debug, Clone, PartialEq)]
pub enum Adjustment {
    /// New batch sizes to apply (these incur the swap/restart cost).
    Apply(Vec<f64>),
    /// Inside the dead-band or not enough observations.
    Hold,
}

/// The closed-loop dynamic batcher (paper §III-C), resizable for
/// elastic membership: [`DynamicBatcher::retire`] removes a worker
/// (water-filling its batch mass onto the survivors) and
/// [`DynamicBatcher::admit`] brings one back with a warm-start batch
/// derived from the controller's smoothed throughput estimates.  The
/// global batch Σb is invariant under adjustments *and* membership
/// transitions, so λ-weighted aggregation (Eq. 2) stays statistically
/// equivalent across epochs.
#[derive(Debug, Clone)]
pub struct DynamicBatcher {
    cfg: ControllerCfg,
    workers: Vec<WorkerState>,
    /// Σb of the initially-live cohort, fixed at construction (invariant
    /// under adjustments and membership epochs).
    global_batch: f64,
    adjustments: usize,
    /// Current required-observation multiplier (see ControllerCfg::backoff).
    backoff_mult: usize,
}

impl DynamicBatcher {
    /// Start from any initial allocation (§III-C: "works with any initial
    /// batch size"; farther from ideal ⇒ more adjustment steps).
    pub fn new(cfg: ControllerCfg, initial: &[f64]) -> Self {
        let live = vec![true; initial.len()];
        Self::with_membership(cfg, initial, &live)
    }

    /// Start with an explicit membership: absent workers (scheduled
    /// `join_at` ranks) carry no batch and no bounds check until
    /// admitted.  Panics on an out-of-bounds initial batch; builder
    /// paths that want a validated error use
    /// [`DynamicBatcher::try_with_membership`] instead.
    pub fn with_membership(cfg: ControllerCfg, initial: &[f64], live: &[bool]) -> Self {
        Self::try_with_membership(cfg, initial, live).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`DynamicBatcher::with_membership`] with validation instead of
    /// asserts: a skewed open-loop allocation (satellite of DESIGN.md
    /// §14) surfaces as an `Err` the Session builder can report, not a
    /// panic inside the controller.
    pub fn try_with_membership(
        cfg: ControllerCfg,
        initial: &[f64],
        live: &[bool],
    ) -> Result<Self, String> {
        if initial.is_empty() {
            return Err("controller needs at least one worker".into());
        }
        if initial.len() != live.len() {
            return Err(format!(
                "batch vector length {} != membership length {}",
                initial.len(),
                live.len()
            ));
        }
        for (w, (&b, &l)) in initial.iter().zip(live).enumerate() {
            if l && !(b >= cfg.b_min && b <= cfg.b_max) {
                return Err(format!(
                    "initial batch {b} for worker {w} out of bounds [{}, {}]",
                    cfg.b_min, cfg.b_max
                ));
            }
        }
        let global_batch = initial
            .iter()
            .zip(live)
            .filter(|(_, &l)| l)
            .map(|(&b, _)| b)
            .sum();
        Ok(DynamicBatcher {
            workers: initial
                .iter()
                .zip(live)
                .map(|(&b, &l)| WorkerState {
                    batch: if l { b } else { 0.0 },
                    ewma: Smoother::new(cfg.ewma_alpha, cfg.drift_reset),
                    b_max: cfg.b_max,
                    last_point: None,
                    cap_age: 0,
                    active: l,
                })
                .collect(),
            cfg,
            global_batch,
            adjustments: 0,
            backoff_mult: 1,
        })
    }

    pub fn k(&self) -> usize {
        self.workers.len()
    }

    pub fn is_active(&self, k: usize) -> bool {
        self.workers[k].active
    }

    pub fn active_count(&self) -> usize {
        self.workers.iter().filter(|w| w.active).count()
    }

    /// Current batch of one worker (0 while retired) — the O(1)
    /// accessor the wrapping policies (optimal/RL) use per observation.
    pub fn batch(&self, k: usize) -> f64 {
        self.workers[k].batch
    }

    /// The configuration this controller runs under (read-only; the
    /// wrapping policies share its bounds and gating knobs).
    pub fn cfg(&self) -> &ControllerCfg {
        &self.cfg
    }

    /// Full-length batch vector; retired workers hold 0.
    pub fn batches(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.workers.len());
        self.batches_into(&mut out);
        out
    }

    /// [`DynamicBatcher::batches`] into a caller-owned buffer (cleared
    /// first) — per-round callers (the Session's membership rebalance,
    /// the figure harness control loops) reuse one allocation across
    /// the whole run, like `ps::lambdas_into` already does.
    pub fn batches_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.workers.iter().map(|w| w.batch));
    }

    /// λ_k = b_k / Σ b_i — the gradient weights (Eq. 2), normalized over
    /// the live cohort (retired workers get λ = 0).
    pub fn lambdas(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.workers.len());
        self.lambdas_into(&mut out);
        out
    }

    /// [`DynamicBatcher::lambdas`] into a caller-owned buffer (cleared
    /// first).
    pub fn lambdas_into(&self, out: &mut Vec<f64>) {
        let total: f64 = self.workers.iter().map(|w| w.batch).sum();
        assert!(total > 0.0, "lambdas of an empty cohort");
        out.clear();
        out.extend(self.workers.iter().map(|w| w.batch / total));
    }

    pub fn global_batch(&self) -> f64 {
        self.global_batch
    }

    pub fn adjustments(&self) -> usize {
        self.adjustments
    }

    /// Feed one iteration-time observation for worker `k`.
    pub fn observe(&mut self, k: usize, iter_time: f64) {
        assert!(iter_time > 0.0, "iteration time must be positive");
        assert!(self.workers[k].active, "observation for retired worker {k}");
        self.workers[k].ewma.push(iter_time);
    }

    /// Smoothed iteration time per worker (None until observed).
    pub fn smoothed(&self) -> Vec<Option<f64>> {
        self.workers.iter().map(|w| w.ewma.get()).collect()
    }

    /// Smoothed iteration time of one worker — the O(1) per-rank
    /// accessor behind [`Self::smoothed`], used by the failure
    /// detector's per-dispatch deadline computation (DESIGN.md §12).
    pub fn smoothed_iter_time(&self, k: usize) -> Option<f64> {
        self.workers[k].ewma.get()
    }

    /// Consume the live cohort's drift flags (true if any smoother
    /// detected a capacity-regime change since the last take).  The
    /// wrapping policies (optimal/RL, DESIGN.md §14) use this to
    /// invalidate model state fitted under the old regime; callers of
    /// [`Self::maybe_adjust`] must NOT also call this — the control
    /// step consumes the same flags for its backoff override.
    pub fn take_drifted(&mut self) -> bool {
        self.workers
            .iter_mut()
            .filter(|w| w.active)
            .map(|w| w.ewma.take_drifted())
            .fold(false, |a, b| a | b)
    }

    // -------------------------------------------------- elastic membership

    /// Retire worker `k` (spot revocation): its batch mass is
    /// water-filled onto the survivors, conserving Σb; its smoothing
    /// window resets (the next admission starts a fresh interval) while
    /// its knee memory is kept as a future warm-start estimate.
    pub fn retire(&mut self, k: usize) {
        assert!(self.workers[k].active, "retire of retired worker {k}");
        self.workers[k].active = false;
        self.workers[k].batch = 0.0;
        self.workers[k].ewma.reset();
        self.rebalance_active();
    }

    /// Re-admit worker `k` with a warm-start batch from the controller's
    /// smoothed throughput estimates: its own remembered throughput when
    /// it has been seen before, else the mean of the live cohort's
    /// estimates (⇒ an equal share).  Survivors are then water-filled
    /// back down so Σb returns to the global target.
    pub fn admit(&mut self, k: usize) {
        assert!(!self.workers[k].active, "admit of active worker {k}");
        let cohort_x: Vec<f64> = self
            .workers
            .iter()
            .filter(|w| w.active)
            .filter_map(|w| w.throughput_estimate())
            .collect();
        let n_active = self.active_count();
        let sum_b: f64 = self
            .workers
            .iter()
            .filter(|w| w.active)
            .map(|w| w.batch)
            .sum();
        // The warm batch is expressed in the *survivors' current batch
        // scale*: the water-fill below rescales everyone proportionally
        // back to the global target, so this lands the cohort on the
        // intended shares (throughput-proportional when estimates exist,
        // an equal split otherwise).
        let warm = if cohort_x.len() == n_active && n_active > 0 && sum_b > 0.0 {
            let sum_x: f64 = cohort_x.iter().sum();
            let x_new = self.workers[k]
                .throughput_estimate()
                .unwrap_or(sum_x / n_active as f64);
            x_new * sum_b / sum_x
        } else if n_active > 0 && sum_b > 0.0 {
            sum_b / n_active as f64
        } else {
            self.global_batch
        };
        let w = &mut self.workers[k];
        w.active = true;
        w.batch = warm.clamp(self.cfg.b_min, w.b_max);
        w.ewma.reset();
        self.rebalance_active();
    }

    /// Water-fill the live cohort's batches to the global target
    /// (conservation across adjustments and membership epochs alike).
    fn rebalance_active(&mut self) {
        let idx: Vec<usize> = (0..self.workers.len())
            .filter(|&i| self.workers[i].active)
            .collect();
        if idx.is_empty() {
            return;
        }
        let mut prop: Vec<f64> = idx.iter().map(|&i| self.workers[i].batch).collect();
        let bmax: Vec<f64> = idx.iter().map(|&i| self.workers[i].b_max).collect();
        water_fill(&mut prop, self.global_batch, self.cfg.b_min, &bmax);
        for (&i, &b) in idx.iter().zip(&prop) {
            self.workers[i].batch = b;
            // Batches changed ⇒ old iteration times are for the wrong
            // batch size: restart the smoothing interval (same rule as
            // an applied adjustment / set_batches).  Warm-start uses
            // last_point, which survives.
            self.workers[i].ewma.reset();
        }
    }

    /// Run the control step ("putting it all together", §III-C):
    /// 1. μ_k from EWMA; 2. Eq. 4–5 proposal; 3. bounds; 4. dead-band.
    /// Retired workers are invisible — the law runs over the live cohort.
    pub fn maybe_adjust(&mut self) -> Adjustment {
        let active: Vec<usize> = (0..self.workers.len())
            .filter(|&i| self.workers[i].active)
            .collect();
        if active.is_empty() {
            return Adjustment::Hold;
        }
        // Need enough fresh observations on every live worker (scaled by
        // the current backoff multiplier) — unless a regime change (drift
        // reset) was just detected, which overrides the backoff so the
        // controller reacts to interference within a few iterations.
        let drifted = self
            .workers
            .iter_mut()
            .filter(|w| w.active)
            .map(|w| w.ewma.take_drifted())
            .fold(false, |a, b| a | b);
        if drifted {
            self.backoff_mult = 1;
        }
        let required = if drifted { 2 } else { self.cfg.min_obs * self.backoff_mult };
        if active
            .iter()
            .any(|&i| self.workers[i].ewma.count() < required || self.workers[i].ewma.get().is_none())
        {
            return Adjustment::Hold;
        }
        let mu: Vec<f64> = active
            .iter()
            .map(|&i| self.workers[i].ewma.get().unwrap())
            .collect();
        let t_bar = mu.iter().sum::<f64>() / mu.len() as f64;

        // Proportional proposal: b' = b · t̄/μ  (equivalent to Δb = −X·τ).
        let mut proposal: Vec<f64> = active
            .iter()
            .zip(&mu)
            .map(|(&i, &m)| self.workers[i].batch * t_bar / m)
            .collect();

        // Bounds + global-batch conservation. Clamping after a plain
        // renormalization would break the paper's Σb = K·b0 invariant
        // whenever a bound binds (e.g. an adaptively-shrunk b_max), so
        // water-fill instead: scale the unclamped workers to absorb what
        // the clamped ones gave up, iterating until no new bound binds
        // (≤ K rounds).
        if self.cfg.conserve_global {
            let bmaxes: Vec<f64> = active.iter().map(|&i| self.workers[i].b_max).collect();
            water_fill(&mut proposal, self.global_batch, self.cfg.b_min, &bmaxes);
        } else {
            for (b, &i) in proposal.iter_mut().zip(&active) {
                *b = b.clamp(self.cfg.b_min, self.workers[i].b_max);
            }
        }

        // Dead-band: act only if the largest relative change is material.
        let max_rel = active
            .iter()
            .zip(&proposal)
            .map(|(&i, &p)| ((p - self.workers[i].batch) / self.workers[i].batch).abs())
            .fold(0.0, f64::max);
        if max_rel <= self.cfg.deadband {
            return Adjustment::Hold;
        }

        // Backoff bookkeeping: small (noise-scale) moves raise the bar for
        // the next adjustment; large (regime-change) moves reset it.
        if self.cfg.backoff {
            if max_rel < 4.0 * self.cfg.deadband.max(0.01) {
                self.backoff_mult = (self.backoff_mult * 2).min(self.cfg.backoff_cap);
            } else {
                self.backoff_mult = 1;
            }
        }

        // Apply: record throughput points for knee detection, then reset
        // the EWMAs (the paper smooths within the interval since the last
        // readjustment only).
        let b_max_cfg = self.cfg.b_max;
        let b_min_cfg = self.cfg.b_min;
        let adaptive = self.cfg.adaptive_bmax;
        for ((&i, &p), &m) in active.iter().zip(&proposal).zip(&mu) {
            let w = &mut self.workers[i];
            let throughput = w.batch / m;
            if adaptive {
                // Expire stale knee caps (periodic re-probing).
                if w.b_max < b_max_cfg {
                    w.cap_age += 1;
                    if w.cap_age >= KNEE_TTL {
                        w.b_max = b_max_cfg;
                        w.cap_age = 0;
                    }
                }
                if let Some((prev_b, prev_x)) = w.last_point {
                    // Raised the batch materially but throughput fell well
                    // beyond noise ⇒ passed the knee (Fig. 5); cap this
                    // worker at the previous batch size. Thresholds are
                    // deliberately conservative (iteration noise is ~5%),
                    // and detection is skipped entirely when this
                    // adjustment was triggered by a capacity-regime drift:
                    // a throughput drop caused by interference would
                    // otherwise masquerade as a memory knee.
                    if !drifted
                        && w.batch > prev_b * 1.02
                        && throughput < prev_x * 0.90
                    {
                        w.b_max = w.b_max.min(prev_b.max(b_min_cfg));
                        w.cap_age = 0;
                    }
                }
                w.last_point = Some((w.batch, throughput));
            }
            // `p` is already bounded by water_fill; a freshly shrunk
            // b_max (knee detection above) applies from the *next*
            // proposal so conservation of this one is preserved.
            w.batch = p;
            w.ewma.reset();
        }
        self.adjustments += 1;
        Adjustment::Apply(self.batches())
    }

    /// Force-set batches (bucket quantization round-trips through this).
    /// Retired workers stay at 0 regardless of the passed value.
    pub fn set_batches(&mut self, batches: &[f64]) {
        assert_eq!(batches.len(), self.workers.len());
        for (w, &b) in self.workers.iter_mut().zip(batches) {
            if w.active {
                w.batch = b.clamp(self.cfg.b_min, w.b_max);
                w.ewma.reset();
            } else {
                w.batch = 0.0;
            }
        }
    }

    // ----------------------------------------------------- checkpointing

    /// Checkpoint snapshot (DESIGN.md §15): the full mutable state —
    /// per-worker batches/bounds/knee memory/smoothers plus the global
    /// counters.  The `ControllerCfg` is *not* persisted here; it is
    /// part of the run config the restorer rebuilds from.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::ckpt::enc_f64;
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("global_batch", enc_f64(self.global_batch));
        j.set("adjustments", Json::Num(self.adjustments as f64));
        j.set("backoff_mult", Json::Num(self.backoff_mult as f64));
        j.set(
            "workers",
            Json::Arr(
                self.workers
                    .iter()
                    .map(|w| {
                        let mut o = Json::obj();
                        o.set("batch", enc_f64(w.batch));
                        o.set("b_max", enc_f64(w.b_max));
                        o.set(
                            "last_point",
                            match w.last_point {
                                Some((b, x)) => Json::Arr(vec![enc_f64(b), enc_f64(x)]),
                                None => Json::Null,
                            },
                        );
                        o.set("cap_age", Json::Num(w.cap_age as f64));
                        o.set("active", Json::Bool(w.active));
                        o.set("ewma", w.ewma.snapshot());
                        o
                    })
                    .collect(),
            ),
        );
        j
    }

    /// Rebuild from a [`DynamicBatcher::snapshot`] under `cfg` (which
    /// must be the same config the run started with — it comes from the
    /// checkpoint's config echo).
    pub fn restore(
        cfg: ControllerCfg,
        j: &crate::util::json::Json,
    ) -> Result<DynamicBatcher, String> {
        use crate::ckpt::{dec_f64, dec_usize};
        let arr = j
            .get("workers")
            .as_arr()
            .ok_or("controller snapshot has no workers array")?;
        let mut workers = Vec::with_capacity(arr.len());
        for w in arr {
            let last_point = match w.get("last_point") {
                crate::util::json::Json::Null => None,
                lp => Some((dec_f64(lp.idx(0))?, dec_f64(lp.idx(1))?)),
            };
            workers.push(WorkerState {
                batch: dec_f64(w.get("batch"))?,
                ewma: Smoother::restore(cfg.ewma_alpha, cfg.drift_reset, w.get("ewma"))?,
                b_max: dec_f64(w.get("b_max"))?,
                last_point,
                cap_age: dec_usize(w.get("cap_age"))?,
                active: w.get("active").as_bool().ok_or("worker active flag missing")?,
            });
        }
        if workers.is_empty() {
            return Err("controller snapshot has zero workers".to_string());
        }
        Ok(DynamicBatcher {
            global_batch: dec_f64(j.get("global_batch"))?,
            adjustments: dec_usize(j.get("adjustments"))?,
            backoff_mult: dec_usize(j.get("backoff_mult"))?,
            workers,
            cfg,
        })
    }
}

/// Adjustments a knee cap survives before being re-probed.
pub const KNEE_TTL: usize = 6;

/// Pseudo-observations a drift reset seeds the smoothing window with
/// (both modes: cumulative mean and EWMA — see `Smoother::push`).
const DRIFT_SEED_N: usize = 3;

/// Scale `proposal` to sum to `target` subject to per-worker bounds
/// [b_min, b_max[i]]: proportional water-filling. Workers pinned at a
/// bound are frozen and the remainder is rescaled over the free set.
///
/// `b_min` is a *hard* bound (a batch below it is invalid). `b_max` is a
/// *soft* bound (it protects throughput, e.g. adaptively-discovered
/// memory knees): when honoring every b_max would make the target
/// unreachable, conservation wins and the deficit is spread across all
/// workers above their caps. If target < Σb_min, everything pins at
/// b_min (the only valid point closest to the target).
pub fn water_fill(proposal: &mut [f64], target: f64, b_min: f64, b_max: &[f64]) {
    assert_eq!(proposal.len(), b_max.len());
    let k = proposal.len();
    let orig: Vec<f64> = proposal.to_vec();
    let mut fixed = vec![false; k];
    for _round in 0..=k {
        let fixed_sum: f64 = (0..k).filter(|&i| fixed[i]).map(|i| proposal[i]).sum();
        let free_sum: f64 = (0..k).filter(|&i| !fixed[i]).map(|i| proposal[i]).sum();
        if free_sum <= 0.0 {
            break;
        }
        let scale = (target - fixed_sum) / free_sum;
        let mut newly_fixed = false;
        for i in 0..k {
            if fixed[i] {
                continue;
            }
            let v = proposal[i] * scale;
            if v < b_min {
                proposal[i] = b_min;
                fixed[i] = true;
                newly_fixed = true;
            } else if v > b_max[i] {
                proposal[i] = b_max[i];
                fixed[i] = true;
                newly_fixed = true;
            }
        }
        if !newly_fixed {
            for i in 0..k {
                if !fixed[i] {
                    proposal[i] *= scale;
                }
            }
            break;
        }
    }
    let sum: f64 = proposal.iter().sum();
    if sum > 0.0 && (sum - target).abs() / target.max(1.0) > 1e-12 && sum < target {
        let max_sum: f64 = b_max.iter().map(|&m| m.max(b_min)).sum();
        if target > max_sum {
            // Conservation dominates soft b_max caps: the caps made the
            // target genuinely unreachable, so spread the deficit
            // proportionally (b_min stays hard).
            let scale = target / sum;
            for p in proposal.iter_mut() {
                *p = (*p * scale).max(b_min);
            }
        } else {
            // The round loop undershot only because b_min- and
            // b_max-pins landed in the same round (a single shared scale
            // pinned low entries that a larger final scale would have
            // left free).  The target *is* reachable inside the box, so
            // project exactly: Σ clamp(orig·s, b_min, b_max) is monotone
            // in s — bisect for the s that restores the target.
            let f = |s: f64| -> f64 {
                orig.iter()
                    .zip(b_max)
                    .map(|(&p, &m)| (p * s).clamp(b_min, m.max(b_min)))
                    .sum()
            };
            let mut hi = 1.0f64;
            let mut guard = 0;
            while f(hi) < target && guard < 200 {
                hi *= 2.0;
                guard += 1;
            }
            let mut lo = 0.0f64;
            for _ in 0..200 {
                let mid = 0.5 * (lo + hi);
                if f(mid) < target {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            for ((p, &o), &m) in proposal.iter_mut().zip(&orig).zip(b_max) {
                *p = (o * hi).clamp(b_min, m.max(b_min));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-9;

    fn feed(ctl: &mut DynamicBatcher, times: &[f64], n: usize) {
        for _ in 0..n {
            for (k, &t) in times.iter().enumerate() {
                ctl.observe(k, t);
            }
        }
    }

    // -------------------------------------------------------- allocators

    #[test]
    fn uniform_is_uniform() {
        assert_eq!(uniform_alloc(64.0, 3), vec![64.0; 3]);
    }

    #[test]
    fn static_alloc_proportional_and_conserving() {
        // Paper §III-B example shape: (3, 5, 12)-core cluster.
        let b = static_alloc(60.0, &[3.0, 5.0, 12.0]);
        assert!((b.iter().sum::<f64>() - 180.0).abs() < EPS);
        assert!((b[2] / b[0] - 4.0).abs() < EPS);
        assert!((b[1] / b[0] - 5.0 / 3.0).abs() < EPS);
    }

    #[test]
    #[should_panic]
    fn static_alloc_rejects_zero_estimate() {
        static_alloc(64.0, &[1.0, 0.0]);
    }

    #[test]
    fn static_alloc_bounded_clamps_skewed_estimates() {
        // FLOPs ratio 100:1 would give the fast worker ~126.7 at b0=64
        // with b_max=100 — the unbounded allocation used to panic the
        // controller's construction-time bounds assert.
        let b = static_alloc_bounded(64.0, &[1.0, 100.0], 1.0, 100.0).unwrap();
        assert!((b.iter().sum::<f64>() - 128.0).abs() < 1e-9, "{b:?}");
        assert!(b.iter().all(|&x| (1.0..=100.0).contains(&x)), "{b:?}");
        // The clamped allocation must be constructible.
        let cfg = ControllerCfg {
            b_min: 1.0,
            b_max: 100.0,
            ..ControllerCfg::default()
        };
        assert!(DynamicBatcher::try_with_membership(cfg, &b, &[true, true]).is_ok());
    }

    #[test]
    fn static_alloc_bounded_is_bitwise_identical_in_bounds() {
        // In-bounds proposals must NOT round-trip through water_fill:
        // the ≈1.0 rescale would move every batch by an ulp and break
        // golden reproducibility.
        let est = [3.0, 5.0, 12.0];
        let plain = static_alloc(60.0, &est);
        let bounded = static_alloc_bounded(60.0, &est, 1.0, 4096.0).unwrap();
        assert_eq!(plain, bounded, "bitwise divergence on the in-bounds path");
    }

    #[test]
    fn static_alloc_bounded_rejects_infeasible_mass() {
        // 2 workers × b0=64 = 128 total, but b_max=50 caps the cohort at
        // 100 — no valid allocation exists.
        assert!(static_alloc_bounded(64.0, &[1.0, 1.0], 1.0, 50.0).is_err());
        // Σ = 4 < 2×b_min.
        assert!(static_alloc_bounded(2.0, &[1.0, 1.0], 8.0, 4096.0).is_err());
        // Zero estimate: validated error, not a panic.
        assert!(static_alloc_bounded(64.0, &[1.0, 0.0], 1.0, 4096.0).is_err());
    }

    #[test]
    fn try_with_membership_reports_out_of_bounds_instead_of_panicking() {
        let err = DynamicBatcher::try_with_membership(
            ControllerCfg::default(),
            &[0.5, 64.0],
            &[true, true],
        )
        .unwrap_err();
        assert!(err.contains("out of bounds"), "{err}");
        // Absent ranks are exempt until admitted, as before.
        assert!(DynamicBatcher::try_with_membership(
            ControllerCfg::default(),
            &[0.0, 64.0],
            &[false, true],
        )
        .is_ok());
    }

    // -------------------------------------------------------- controller

    #[test]
    fn drift_reset_seeds_both_smoothing_modes_equivalently() {
        // Satellite of DESIGN.md §14: the cumulative-mean branch used to
        // restart at n = 3 pseudo-observations while the EWMA branch got
        // a single push — the two smoothing modes disagreed on the
        // post-drift warm-start weight.  Both must now restart from an
        // identical state: the same estimate, carried by the same
        // DRIFT_SEED_N pseudo-observations.
        for alpha in [0.0, 0.05] {
            let mut s = Smoother::new(alpha, 0.15);
            for _ in 0..8 {
                s.push(1.0);
            }
            let mut fired = false;
            for _ in 0..12 {
                s.push(4.0);
                if s.seeded() {
                    fired = true;
                    break;
                }
            }
            assert!(fired, "alpha={alpha}: drift reset never fired");
            let med = s.get().unwrap();
            assert_eq!(s.count(), DRIFT_SEED_N, "alpha={alpha}");
            assert_eq!(s.ewma.count(), DRIFT_SEED_N, "alpha={alpha}");
            assert!(
                (s.ewma.get().unwrap() - med).abs() < 1e-12,
                "alpha={alpha}: EWMA warm start diverges from the estimate"
            );
            assert!(
                (s.sum - med * DRIFT_SEED_N as f64).abs() < 1e-12,
                "alpha={alpha}: cumulative warm start diverges from the estimate"
            );
            assert!(s.take_drifted());
        }
    }

    #[test]
    fn needs_min_obs_before_acting() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[64.0, 64.0]);
        ctl.observe(0, 1.0);
        ctl.observe(1, 2.0);
        assert_eq!(ctl.maybe_adjust(), Adjustment::Hold);
    }

    #[test]
    fn equal_times_hold() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[64.0, 64.0, 64.0]);
        feed(&mut ctl, &[1.0, 1.0, 1.0], 5);
        assert_eq!(ctl.maybe_adjust(), Adjustment::Hold);
        assert_eq!(ctl.adjustments(), 0);
    }

    #[test]
    fn slower_worker_shrinks_faster_grows() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[64.0, 64.0]);
        // Worker 0 takes 2s, worker 1 takes 1s at the same batch.
        feed(&mut ctl, &[2.0, 1.0], 5);
        match ctl.maybe_adjust() {
            Adjustment::Apply(b) => {
                assert!(b[0] < 64.0, "slow worker must shrink: {b:?}");
                assert!(b[1] > 64.0, "fast worker must grow: {b:?}");
            }
            Adjustment::Hold => panic!("expected adjustment"),
        }
    }

    #[test]
    fn global_batch_conserved() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[32.0, 64.0, 96.0]);
        feed(&mut ctl, &[3.0, 1.0, 0.7], 5);
        if let Adjustment::Apply(b) = ctl.maybe_adjust() {
            assert!(
                (b.iter().sum::<f64>() - 192.0).abs() < 1e-6,
                "sum {} != 192",
                b.iter().sum::<f64>()
            );
        } else {
            panic!("expected adjustment");
        }
    }

    #[test]
    fn paper_closed_form_single_step() {
        // §III-C: b¹ = b⁰ · t̄/t. With no bounds/deadband interference and
        // equal initial batches, t=(2,1) ⇒ t̄=1.5 ⇒ proposals (48, 96)
        // before conservation; conservation keeps sum at 128 ⇒ (48, 96)·
        // (128/144) = (42.67, 85.33).
        let cfg = ControllerCfg {
            deadband: 0.0,
            ..ControllerCfg::default()
        };
        let mut ctl = DynamicBatcher::new(cfg, &[64.0, 64.0]);
        feed(&mut ctl, &[2.0, 1.0], 5);
        if let Adjustment::Apply(b) = ctl.maybe_adjust() {
            assert!((b[0] - 128.0 / 3.0).abs() < 1e-6, "{b:?}");
            assert!((b[1] - 256.0 / 3.0).abs() < 1e-6, "{b:?}");
        } else {
            panic!();
        }
    }

    #[test]
    fn converges_to_throughput_proportional_in_two_steps() {
        // Fig. 4a: equal initial batches on (1x, 2x, 4x) workers converge
        // within ~2 adjustments. Simulate linear-time workers:
        // t_k = b_k / X_k with X = (10, 20, 40) samples/s.
        let xs = [10.0, 20.0, 40.0];
        let cfg = ControllerCfg {
            deadband: 0.05,
            min_obs: 1,
            ..ControllerCfg::default()
        };
        let mut ctl = DynamicBatcher::new(cfg, &[64.0, 64.0, 64.0]);
        for _step in 0..4 {
            let b = ctl.batches();
            for k in 0..3 {
                ctl.observe(k, b[k] / xs[k]);
            }
            ctl.maybe_adjust();
        }
        let b = ctl.batches();
        let total: f64 = b.iter().sum();
        // Ideal: proportional to X ⇒ (1/7, 2/7, 4/7) of 192.
        assert!((total - 192.0).abs() < 1e-6);
        assert!((b[0] / total - 1.0 / 7.0).abs() < 0.02, "{b:?}");
        assert!((b[2] / total - 4.0 / 7.0).abs() < 0.02, "{b:?}");
        // And it should now be in steady state (dead-band holds).
        for k in 0..3 {
            ctl.observe(k, b[k] / xs[k]);
        }
        assert_eq!(ctl.maybe_adjust(), Adjustment::Hold);
        assert!(ctl.adjustments() <= 3, "took {} adjustments", ctl.adjustments());
    }

    #[test]
    fn deadband_suppresses_oscillation_noise() {
        // Fig. 4b: without a dead-band, stochastic time noise causes
        // endless oscillation; with it, steady state is quiet.
        use crate::util::rng::Rng;
        let xs = [10.0, 40.0];
        let run = |deadband: f64| {
            let cfg = ControllerCfg {
                deadband,
                min_obs: 1,
                backoff: false, // isolate the dead-band mechanism
                ..ControllerCfg::default()
            };
            // Start at the ideal allocation.
            let mut ctl = DynamicBatcher::new(cfg, &[25.6, 102.4]);
            let mut rng = Rng::new(0);
            for _ in 0..100 {
                let b = ctl.batches();
                for k in 0..2 {
                    let noise = rng.lognormal(1.0, 0.04);
                    ctl.observe(k, b[k] / xs[k] * noise);
                }
                ctl.maybe_adjust();
            }
            ctl.adjustments()
        };
        let with_db = run(0.05);
        let without_db = run(0.0);
        assert!(
            without_db > 10 * with_db.max(1),
            "deadband={with_db} nodeadband={without_db}"
        );
        assert!(with_db <= 2, "steady state should be quiet: {with_db}");
    }

    #[test]
    fn bounds_respected() {
        let cfg = ControllerCfg {
            b_min: 8.0,
            b_max: 100.0,
            conserve_global: false,
            ..ControllerCfg::default()
        };
        let mut ctl = DynamicBatcher::new(cfg, &[64.0, 64.0]);
        // Extreme imbalance wants b0 → ~0 and b1 → huge.
        feed(&mut ctl, &[100.0, 0.01], 5);
        if let Adjustment::Apply(b) = ctl.maybe_adjust() {
            assert!(b[0] >= 8.0 - EPS, "{b:?}");
            assert!(b[1] <= 100.0 + EPS, "{b:?}");
        } else {
            panic!();
        }
    }

    #[test]
    fn adaptive_bmax_caps_after_throughput_drop() {
        let cfg = ControllerCfg {
            min_obs: 1,
            conserve_global: false,
            ..ControllerCfg::default()
        };
        let mut ctl = DynamicBatcher::new(cfg, &[50.0, 50.0]);
        // Step 1: worker 1 is fast at b=50 (X=50), worker 0 slower.
        ctl.observe(0, 2.0); // X0 = 25
        ctl.observe(1, 1.0); // X1 = 50
        ctl.maybe_adjust();
        let b_after_1 = ctl.batches()[1];
        assert!(b_after_1 > 50.0);
        // Step 2: worker 1's batch grew but its throughput *fell* (past
        // the knee): report a time that implies X < 50·0.98.
        ctl.observe(0, 1.0);
        ctl.observe(1, b_after_1 / 30.0); // X1 = 30 < 49
        ctl.maybe_adjust();
        // Step 3: any further proposal for worker 1 is capped at 50.
        ctl.observe(0, 5.0);
        ctl.observe(1, 0.1);
        ctl.maybe_adjust();
        assert!(
            ctl.batches()[1] <= 50.0 + EPS,
            "b1={} should be capped at the knee",
            ctl.batches()[1]
        );
    }

    #[test]
    fn lambdas_sum_to_one_and_track_batches() {
        let ctl = DynamicBatcher::new(ControllerCfg::default(), &[30.0, 60.0, 90.0]);
        let l = ctl.lambdas();
        assert!((l.iter().sum::<f64>() - 1.0).abs() < EPS);
        assert!((l[2] / l[0] - 3.0).abs() < EPS);
    }

    #[test]
    fn into_variants_match_and_clear_scratch() {
        let ctl = DynamicBatcher::new(ControllerCfg::default(), &[30.0, 60.0, 90.0]);
        let mut scratch = vec![999.0; 7]; // stale content must be cleared
        ctl.batches_into(&mut scratch);
        assert_eq!(scratch, ctl.batches());
        ctl.lambdas_into(&mut scratch);
        assert_eq!(scratch, ctl.lambdas());
    }

    #[test]
    fn set_batches_clamps() {
        let cfg = ControllerCfg {
            b_min: 4.0,
            b_max: 128.0,
            ..ControllerCfg::default()
        };
        let mut ctl = DynamicBatcher::new(cfg, &[64.0, 64.0]);
        ctl.set_batches(&[1.0, 500.0]);
        assert_eq!(ctl.batches(), vec![4.0, 128.0]);
    }

    #[test]
    #[should_panic]
    fn observe_rejects_nonpositive_time() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[64.0]);
        ctl.observe(0, 0.0);
    }

    // ------------------------------------------------- elastic membership

    #[test]
    fn retire_water_fills_mass_onto_survivors() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[32.0, 64.0, 96.0]);
        ctl.retire(0);
        let b = ctl.batches();
        assert_eq!(b[0], 0.0);
        // Σb conserved; survivors keep their 64:96 = 2:3 proportion.
        assert!((b.iter().sum::<f64>() - 192.0).abs() < EPS, "{b:?}");
        assert!((b[2] / b[1] - 1.5).abs() < 1e-9, "{b:?}");
        assert_eq!(ctl.active_count(), 2);
        assert!(!ctl.is_active(0));
        // λ re-normalizes over the survivors.
        let l = ctl.lambdas();
        assert_eq!(l[0], 0.0);
        assert!((l[1] + l[2] - 1.0).abs() < EPS);
    }

    #[test]
    fn retire_then_admit_restores_sum_and_lambdas() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[40.0, 80.0, 120.0]);
        ctl.retire(1);
        ctl.admit(1);
        let b = ctl.batches();
        assert!((b.iter().sum::<f64>() - 240.0).abs() < 1e-6, "{b:?}");
        assert!(b.iter().all(|&x| x > 0.0), "{b:?}");
        let l = ctl.lambdas();
        assert!((l.iter().sum::<f64>() - 1.0).abs() < EPS);
        assert_eq!(ctl.active_count(), 3);
    }

    #[test]
    fn admit_cold_cohort_gets_equal_share() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[60.0, 60.0, 60.0]);
        ctl.retire(2);
        // No observations anywhere: the rejoiner gets an equal share.
        ctl.admit(2);
        let b = ctl.batches();
        assert!((b[2] - 60.0).abs() < 1e-6, "{b:?}");
        assert!((b.iter().sum::<f64>() - 180.0).abs() < 1e-6);
    }

    #[test]
    fn admit_warm_starts_from_throughput_estimates() {
        // Converge on a 1:3 cluster so last_point carries real estimates,
        // then bounce worker 0: its warm-start batch must come back near
        // its known (slow) share, not an equal split.
        let cfg = ControllerCfg {
            min_obs: 1,
            deadband: 0.0,
            ..ControllerCfg::default()
        };
        let xs = [10.0, 30.0];
        let mut ctl = DynamicBatcher::new(cfg, &[64.0, 64.0]);
        for _ in 0..6 {
            let b = ctl.batches();
            for k in 0..2 {
                ctl.observe(k, b[k] / xs[k]);
            }
            ctl.maybe_adjust();
        }
        ctl.retire(0);
        ctl.admit(0);
        let b = ctl.batches();
        assert!((b.iter().sum::<f64>() - 128.0).abs() < 1e-6, "{b:?}");
        // Throughput-proportional: worker 0 ≈ 1/4 of the global batch.
        assert!((b[0] / 128.0 - 0.25).abs() < 0.05, "warm start {b:?}");
    }

    #[test]
    fn retired_worker_is_invisible_to_the_control_law() {
        let cfg = ControllerCfg {
            min_obs: 2,
            ..ControllerCfg::default()
        };
        let mut ctl = DynamicBatcher::new(cfg, &[64.0, 64.0, 64.0]);
        ctl.retire(2);
        // Only live workers observe; the law must act without rank 2.
        for _ in 0..3 {
            ctl.observe(0, 2.0);
            ctl.observe(1, 1.0);
        }
        match ctl.maybe_adjust() {
            Adjustment::Apply(b) => {
                assert_eq!(b[2], 0.0, "{b:?}");
                assert!(b[0] < b[1], "{b:?}");
                assert!((b.iter().sum::<f64>() - 192.0).abs() < 1e-6, "{b:?}");
            }
            Adjustment::Hold => panic!("controller held with a retired rank"),
        }
    }

    #[test]
    fn with_membership_starts_absent_ranks_at_zero() {
        let ctl = DynamicBatcher::with_membership(
            ControllerCfg::default(),
            &[64.0, 64.0, 0.0],
            &[true, true, false],
        );
        assert_eq!(ctl.global_batch(), 128.0);
        assert_eq!(ctl.batches(), vec![64.0, 64.0, 0.0]);
        assert!(!ctl.is_active(2));
    }

    #[test]
    fn set_batches_leaves_retired_at_zero() {
        let mut ctl = DynamicBatcher::new(ControllerCfg::default(), &[64.0, 64.0]);
        ctl.retire(0);
        ctl.set_batches(&[32.0, 128.0]);
        assert_eq!(ctl.batches()[0], 0.0);
        assert_eq!(ctl.batches()[1], 128.0);
    }

    #[test]
    fn water_fill_plain_renormalization() {
        let mut p = vec![10.0, 30.0];
        water_fill(&mut p, 80.0, 1.0, &[1000.0, 1000.0]);
        assert!((p[0] - 20.0).abs() < EPS && (p[1] - 60.0).abs() < EPS);
    }

    #[test]
    fn water_fill_redistributes_clamped_excess() {
        // Worker 1 capped at 50; its excess goes to worker 0.
        let mut p = vec![50.0, 150.0];
        water_fill(&mut p, 200.0, 1.0, &[1000.0, 50.0]);
        assert!((p[1] - 50.0).abs() < EPS, "{p:?}");
        assert!((p[0] - 150.0).abs() < EPS, "{p:?}");
        assert!((p.iter().sum::<f64>() - 200.0).abs() < EPS);
    }

    #[test]
    fn water_fill_respects_b_min() {
        let mut p = vec![1.0, 199.0];
        water_fill(&mut p, 100.0, 8.0, &[1000.0, 1000.0]);
        assert!(p[0] >= 8.0 - EPS);
        assert!((p.iter().sum::<f64>() - 100.0).abs() < EPS, "{p:?}");
    }

    #[test]
    fn water_fill_target_beats_soft_bmax() {
        // Target above Σb_max: conservation wins, caps are exceeded
        // proportionally (b_max is a soft throughput guard).
        let mut p = vec![10.0, 10.0];
        water_fill(&mut p, 500.0, 1.0, &[40.0, 60.0]);
        assert!((p.iter().sum::<f64>() - 500.0).abs() < EPS, "{p:?}");
        assert!(p[1] > p[0]);
    }

    #[test]
    fn water_fill_bmin_is_hard() {
        // Target below Σb_min: everything pins at b_min.
        let mut p = vec![10.0, 10.0];
        water_fill(&mut p, 4.0, 8.0, &[100.0, 100.0]);
        assert_eq!(p, vec![8.0, 8.0]);
    }

    #[test]
    fn snapshot_restore_replays_bitwise() {
        // Checkpoint mid-flight (after observations, an adjustment, and
        // churn), restore through the JSON text round-trip, then drive
        // both controllers identically: every subsequent decision and
        // batch must match to the bit.
        let cfg = ControllerCfg {
            min_obs: 2,
            ..ControllerCfg::default()
        };
        let mut a = DynamicBatcher::new(cfg.clone(), &[64.0, 64.0, 64.0]);
        feed(&mut a, &[2.0, 1.0, 0.7], 2);
        a.maybe_adjust();
        a.retire(2);
        a.observe(0, 1.9);
        a.observe(1, 1.1);
        let text = a.snapshot().to_string();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let mut b = DynamicBatcher::restore(cfg, &j).unwrap();
        assert_eq!(a.batches(), b.batches());
        for round in 0..6 {
            if round == 2 {
                a.admit(2);
                b.admit(2);
            }
            for (k, t) in [(0usize, 2.1), (1, 0.9)] {
                a.observe(k, t);
                b.observe(k, t);
            }
            assert_eq!(a.maybe_adjust(), b.maybe_adjust(), "round {round}");
            let (ba, bb) = (a.batches(), b.batches());
            for (x, y) in ba.iter().zip(&bb) {
                assert_eq!(x.to_bits(), y.to_bits(), "round {round}");
            }
        }
        assert_eq!(a.adjustments(), b.adjustments());
    }
}
