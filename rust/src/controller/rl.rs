//! Tabular bandit/RL batch policy (DYNAMIX, PAPERS.md; DESIGN.md §14).
//!
//! The policy observes the cohort's *imbalance ratio* r = μ_slow/μ_fast
//! (smoothed iteration times), quantizes it into [`N_STATES`] buckets,
//! and picks one of [`N_ACTIONS`] grid moves: hold, or shift a fixed
//! fraction (0.10/0.25/0.50) of the slowest worker's batch onto the
//! fastest.  Every action conserves Σb by construction — mass only
//! moves between two live ranks — so the λ-weighted aggregation (Eq. 2)
//! stays valid without renormalization.
//!
//! The Q-table is trained *offline* over seeded [`crate::cluster::CapacityModel`]
//! episodes ([`train`]) — the same capacity substrate `SimBackend`
//! wraps, so the learned preferences transfer to full Session runs —
//! and serialized as JSON ([`RlTable::to_json`]/[`RlTable::parse`]).
//! The committed default table lives in `src/controller/rl_table.json`
//! (regenerate with `UPDATE_RL_TABLE=1 cargo test -p hetero-batch
//! rl_table_regen`); `--policy rl:<table.json>` loads a custom one.

use super::{Adjustment, BatchPolicy, ControllerCfg, DynamicBatcher};
use crate::cluster::{CapacityModel, DeviceKind, WorkloadProfile};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Imbalance-ratio buckets: [1, 1.05), [1.05, 1.2), [1.2, 1.5),
/// [1.5, 2.5), [2.5, ∞).
pub const N_STATES: usize = 5;
const STATE_EDGES: [f64; N_STATES - 1] = [1.05, 1.2, 1.5, 2.5];

/// hold + three move sizes (fraction of the slowest worker's batch).
pub const N_ACTIONS: usize = 4;
pub const MOVE_FRACTIONS: [f64; N_ACTIONS - 1] = [0.10, 0.25, 0.50];

/// Committed default Q-table (see module docs for regeneration).
pub const DEFAULT_TABLE: &str = include_str!("rl_table.json");

/// Quantize an imbalance ratio μ_slow/μ_fast into its state bucket.
pub fn imbalance_state(r: f64) -> usize {
    STATE_EDGES
        .iter()
        .position(|&edge| r < edge)
        .unwrap_or(N_STATES - 1)
}

/// (slowest, fastest) live worker by smoothed iteration time; ties
/// break toward the lowest rank so the policy is deterministic.
fn slow_fast(times: &[(usize, f64)]) -> Option<(usize, usize)> {
    let slow = times
        .iter()
        .copied()
        .reduce(|a, b| if b.1 > a.1 { b } else { a })?;
    let fast = times
        .iter()
        .copied()
        .reduce(|a, b| if b.1 < a.1 { b } else { a })?;
    Some((slow.0, fast.0))
}

/// Largest admissible slow→fast move: the requested fraction of the
/// slow batch, shrunk so neither endpoint leaves [b_min, b_max].
fn bounded_move(b_slow: f64, b_fast: f64, frac: f64, b_min: f64, b_max: f64) -> f64 {
    (frac * b_slow)
        .min(b_slow - b_min)
        .min(b_max - b_fast)
        .max(0.0)
}

/// The learned action-value table, JSON-serializable.
#[derive(Debug, Clone, PartialEq)]
pub struct RlTable {
    pub q: [[f64; N_ACTIONS]; N_STATES],
}

impl RlTable {
    /// All-zero table (training start state).
    pub fn zeros() -> Self {
        RlTable {
            q: [[0.0; N_ACTIONS]; N_STATES],
        }
    }

    /// The committed default table.
    pub fn builtin() -> Self {
        Self::parse(DEFAULT_TABLE).expect("committed rl_table.json must parse")
    }

    /// Greedy action for a state; ties break toward the lowest action
    /// index (hold first) so the policy is deterministic.
    pub fn greedy(&self, state: usize) -> usize {
        let row = &self.q[state];
        let mut best = 0;
        for (a, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = a;
            }
        }
        best
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("format", Json::Str("hbatch-rl-table-v1".into()));
        o.set(
            "states",
            Json::Arr(
                (0..N_STATES)
                    .map(|s| {
                        let lo = if s == 0 { 1.0 } else { STATE_EDGES[s - 1] };
                        let hi = STATE_EDGES
                            .get(s)
                            .map_or("inf".to_string(), |e| format!("{e}"));
                        Json::Str(format!("ratio[{lo},{hi})"))
                    })
                    .collect(),
            ),
        );
        let mut actions = vec![Json::Str("hold".into())];
        actions.extend(
            MOVE_FRACTIONS
                .iter()
                .map(|f| Json::Str(format!("move{f:.2}"))),
        );
        o.set("actions", Json::Arr(actions));
        o.set(
            "q",
            Json::Arr(
                self.q
                    .iter()
                    .map(|row| Json::from_f64_slice(row))
                    .collect(),
            ),
        );
        o
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let rows = j
            .get("q")
            .as_arr()
            .ok_or("rl table: missing \"q\" array")?;
        if rows.len() != N_STATES {
            return Err(format!(
                "rl table: {} state rows, expected {N_STATES}",
                rows.len()
            ));
        }
        let mut q = [[0.0; N_ACTIONS]; N_STATES];
        for (s, row) in rows.iter().enumerate() {
            let vals = row
                .as_arr()
                .ok_or(format!("rl table: q[{s}] is not an array"))?;
            if vals.len() != N_ACTIONS {
                return Err(format!(
                    "rl table: q[{s}] has {} actions, expected {N_ACTIONS}",
                    vals.len()
                ));
            }
            for (a, v) in vals.iter().enumerate() {
                q[s][a] = v
                    .as_f64()
                    .ok_or(format!("rl table: q[{s}][{a}] is not a number"))?;
            }
        }
        Ok(RlTable { q })
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let j = Json::parse(text).map_err(|e| format!("rl table: {e:?}"))?;
        Self::from_json(&j)
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("rl table {path}: {e}"))?;
        Self::parse(&text)
    }
}

/// Tabular bandit batch policy: greedy over the learned Q-table.
///
/// Wraps a [`DynamicBatcher`] for membership/warm-start bookkeeping
/// and the smoothed estimates, like [`super::OptimalBatcher`]; only the
/// decision rule differs.
#[derive(Debug, Clone)]
pub struct RlBatcher {
    inner: DynamicBatcher,
    table: RlTable,
    /// Observations per worker in the current decision interval.
    interval: Vec<usize>,
    adjustments: usize,
}

impl RlBatcher {
    pub fn new(cfg: ControllerCfg, initial: &[f64], table: RlTable) -> Self {
        let live = vec![true; initial.len()];
        Self::try_with_membership(cfg, initial, &live, table)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn try_with_membership(
        cfg: ControllerCfg,
        initial: &[f64],
        live: &[bool],
        table: RlTable,
    ) -> Result<Self, String> {
        let inner = DynamicBatcher::try_with_membership(cfg, initial, live)?;
        let interval = vec![0; initial.len()];
        Ok(RlBatcher {
            inner,
            table,
            interval,
            adjustments: 0,
        })
    }

    fn reset_intervals(&mut self) {
        for n in &mut self.interval {
            *n = 0;
        }
    }

    /// Rebuild from a [`BatchPolicy::snapshot`] under `cfg` (from the
    /// checkpoint's config echo).  The Q-table travels *inside* the
    /// snapshot, so restore never re-reads the table file.
    pub fn restore(cfg: ControllerCfg, j: &Json) -> Result<RlBatcher, String> {
        use crate::ckpt::dec_usize;
        let inner = DynamicBatcher::restore(cfg, j.get("inner"))?;
        let table = RlTable::from_json(j.get("table"))?;
        let ivals = j
            .get("interval")
            .as_arr()
            .ok_or("rl snapshot has no interval array")?;
        if ivals.len() != inner.k() {
            return Err(format!(
                "rl snapshot: {} interval counters for {} workers",
                ivals.len(),
                inner.k()
            ));
        }
        let interval = ivals
            .iter()
            .map(dec_usize)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(RlBatcher {
            inner,
            table,
            interval,
            adjustments: dec_usize(j.get("adjustments"))?,
        })
    }
}

impl BatchPolicy for RlBatcher {
    fn observe(&mut self, k: usize, iter_time: f64) {
        self.interval[k] += 1;
        self.inner.observe(k, iter_time);
    }

    fn maybe_adjust(&mut self) -> Adjustment {
        // A capacity-regime drift restarts the decision interval: the
        // smoothed times mix two regimes.
        if self.inner.take_drifted() {
            self.reset_intervals();
            return Adjustment::Hold;
        }
        let k = self.inner.k();
        let active: Vec<usize> = (0..k).filter(|&i| self.inner.is_active(i)).collect();
        if active.len() < 2 {
            return Adjustment::Hold;
        }
        let min_obs = self.inner.cfg().min_obs.max(1);
        if active.iter().any(|&i| self.interval[i] < min_obs) {
            return Adjustment::Hold;
        }
        let times: Vec<(usize, f64)> = match active
            .iter()
            .map(|&i| self.inner.smoothed_iter_time(i).map(|t| (i, t)))
            .collect::<Option<Vec<_>>>()
        {
            Some(t) => t,
            None => return Adjustment::Hold,
        };
        let (slow, fast) = match slow_fast(&times) {
            Some(sf) => sf,
            None => return Adjustment::Hold,
        };
        let t_slow = times.iter().find(|&&(i, _)| i == slow).unwrap().1;
        let t_fast = times.iter().find(|&&(i, _)| i == fast).unwrap().1;
        self.reset_intervals();
        if slow == fast || t_fast <= 0.0 {
            return Adjustment::Hold;
        }
        let action = self.table.greedy(imbalance_state(t_slow / t_fast));
        if action == 0 {
            return Adjustment::Hold;
        }
        let cfg = self.inner.cfg();
        let moved = bounded_move(
            self.inner.batch(slow),
            self.inner.batch(fast),
            MOVE_FRACTIONS[action - 1],
            cfg.b_min,
            cfg.b_max,
        );
        if moved <= 1e-9 {
            return Adjustment::Hold;
        }
        let mut full = self.inner.batches();
        full[slow] -= moved;
        full[fast] += moved;
        self.inner.set_batches(&full);
        self.adjustments += 1;
        Adjustment::Apply(full)
    }

    fn retire(&mut self, k: usize) {
        self.inner.retire(k);
        self.reset_intervals();
    }

    fn admit(&mut self, k: usize) {
        self.inner.admit(k);
        self.reset_intervals();
    }

    fn set_batches(&mut self, batches: &[f64]) {
        self.inner.set_batches(batches);
        self.reset_intervals();
    }

    fn batches_into(&self, out: &mut Vec<f64>) {
        self.inner.batches_into(out);
    }

    fn lambdas_into(&self, out: &mut Vec<f64>) {
        self.inner.lambdas_into(out);
    }

    fn smoothed_iter_time(&self, k: usize) -> Option<f64> {
        self.inner.smoothed_iter_time(k)
    }

    fn global_batch(&self) -> f64 {
        self.inner.global_batch()
    }

    fn adjustments(&self) -> usize {
        self.adjustments
    }

    fn label(&self) -> &'static str {
        "rl"
    }

    fn snapshot(&self) -> Json {
        let mut j = Json::obj();
        j.set("inner", self.inner.snapshot());
        j.set("table", self.table.to_json());
        j.set(
            "interval",
            Json::Arr(self.interval.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        j.set("adjustments", Json::Num(self.adjustments as f64));
        j
    }
}

// ===================================================================
// Offline training (seeded, deterministic)

/// Q-learning hyperparameters for [`train`].
#[derive(Debug, Clone)]
pub struct TrainCfg {
    /// Independent seeded episodes (heterogeneous CPU clusters).
    pub episodes: usize,
    /// Decision steps per episode.
    pub steps: usize,
    /// Learning rate.
    pub alpha: f64,
    /// Discount factor.
    pub gamma: f64,
    /// Exploration rate (ε-greedy during training only).
    pub epsilon: f64,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            episodes: 400,
            steps: 25,
            alpha: 0.1,
            gamma: 0.9,
            epsilon: 0.2,
            seed: 7,
        }
    }
}

/// Mean of `n` sampled iteration times per worker, plus the round time
/// (their max — BSP semantics).
fn probe(
    model: &CapacityModel,
    devices: &[DeviceKind],
    batches: &[f64],
    n: usize,
    rng: &mut Rng,
) -> (Vec<f64>, f64) {
    let times: Vec<f64> = devices
        .iter()
        .zip(batches)
        .map(|(d, &b)| {
            (0..n)
                .map(|_| model.iter_time(d, b.max(1.0), 1.0, rng))
                .sum::<f64>()
                / n as f64
        })
        .collect();
    let round = times.iter().copied().fold(0.0, f64::max);
    (times, round)
}

/// Offline tabular Q-learning over seeded [`CapacityModel`] episodes —
/// the same capacity substrate `SimBackend` wraps, so thousands of
/// episodes cost milliseconds and the learned table transfers to full
/// Session runs.  Deterministic in `cfg.seed` (episode RNG streams are
/// forked per episode index).
///
/// Reward: relative BSP round-time improvement of the move, minus a
/// small per-action cost (the readjustment overhead analogue) so the
/// table learns to *hold* near balance.
pub fn train(cfg: &TrainCfg) -> RlTable {
    const CORE_CHOICES: [usize; 5] = [2, 4, 8, 12, 16];
    const ACTION_COST: f64 = 0.02;
    const PROBE_ITERS: usize = 3;
    let mut table = RlTable::zeros();
    let mut root = Rng::new(cfg.seed);
    let ctl = ControllerCfg::default();
    for ep in 0..cfg.episodes {
        let mut rng = root.fork(ep as u64);
        let k = 2 + rng.below(3) as usize;
        let devices: Vec<DeviceKind> = (0..k)
            .map(|_| DeviceKind::Cpu {
                cores: CORE_CHOICES[rng.below(CORE_CHOICES.len() as u64) as usize],
            })
            .collect();
        let model = CapacityModel::new(WorkloadProfile::resnet()).with_noise(0.04);
        let mut batches = vec![64.0; k];
        let (mut times, mut round) =
            probe(&model, &devices, &batches, PROBE_ITERS, &mut rng);
        for _step in 0..cfg.steps {
            let indexed: Vec<(usize, f64)> =
                times.iter().copied().enumerate().collect();
            let (slow, fast) = slow_fast(&indexed).expect("non-empty episode");
            if times[fast] <= 0.0 {
                break;
            }
            let s = imbalance_state(times[slow] / times[fast]);
            let a = if rng.f64() < cfg.epsilon {
                rng.below(N_ACTIONS as u64) as usize
            } else {
                table.greedy(s)
            };
            if a > 0 && slow != fast {
                let m = bounded_move(
                    batches[slow],
                    batches[fast],
                    MOVE_FRACTIONS[a - 1],
                    ctl.b_min,
                    ctl.b_max,
                );
                batches[slow] -= m;
                batches[fast] += m;
            }
            let (nt, nr) = probe(&model, &devices, &batches, PROBE_ITERS, &mut rng);
            let reward =
                (round - nr) / round - if a > 0 { ACTION_COST } else { 0.0 };
            let indexed: Vec<(usize, f64)> = nt.iter().copied().enumerate().collect();
            let (ns_slow, ns_fast) = slow_fast(&indexed).expect("non-empty episode");
            let s_next = imbalance_state(nt[ns_slow] / nt[ns_fast]);
            let best_next = table.q[s_next]
                .iter()
                .copied()
                .fold(f64::NEG_INFINITY, f64::max);
            table.q[s][a] +=
                cfg.alpha * (reward + cfg.gamma * best_next - table.q[s][a]);
            times = nt;
            round = nr;
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_bucketing_covers_the_ratio_line() {
        assert_eq!(imbalance_state(1.0), 0);
        assert_eq!(imbalance_state(1.049), 0);
        assert_eq!(imbalance_state(1.05), 1);
        assert_eq!(imbalance_state(1.3), 2);
        assert_eq!(imbalance_state(2.0), 3);
        assert_eq!(imbalance_state(7.5), 4);
    }

    #[test]
    fn committed_table_parses_and_round_trips() {
        let t = RlTable::builtin();
        let back = RlTable::from_json(&t.to_json()).unwrap();
        assert_eq!(t, back);
        // The committed policy holds at balance and moves mass under
        // imbalance — the minimum for steady state to exist.
        assert_eq!(t.greedy(0), 0, "balanced state must hold");
        for s in 1..N_STATES {
            assert!(t.greedy(s) > 0, "imbalanced state {s} must act");
        }
    }

    #[test]
    fn greedy_ties_break_toward_hold() {
        let t = RlTable::zeros();
        for s in 0..N_STATES {
            assert_eq!(t.greedy(s), 0);
        }
    }

    #[test]
    fn parse_rejects_malformed_tables() {
        assert!(RlTable::parse("{}").is_err());
        assert!(RlTable::parse(r#"{"q": [[1,2],[3,4]]}"#).is_err());
        assert!(RlTable::parse(r#"{"q": "nope"}"#).is_err());
    }

    #[test]
    fn training_is_deterministic() {
        let cfg = TrainCfg {
            episodes: 12,
            steps: 8,
            ..TrainCfg::default()
        };
        let a = train(&cfg);
        let b = train(&cfg);
        assert_eq!(a, b, "same seed must reproduce the table bitwise");
    }

    #[test]
    fn rl_batcher_moves_mass_slow_to_fast_and_conserves() {
        let cfg = ControllerCfg {
            min_obs: 2,
            ..ControllerCfg::default()
        };
        let mut ctl = RlBatcher::new(cfg, &[64.0, 64.0], RlTable::builtin());
        // Worker 0 is 3x slower: ratio 3.0 → state 4 → a big move.
        for _ in 0..3 {
            ctl.observe(0, 9.0);
            ctl.observe(1, 3.0);
        }
        let adj = ctl.maybe_adjust();
        let b = match adj {
            Adjustment::Apply(b) => b,
            Adjustment::Hold => panic!("imbalance must trigger a move"),
        };
        assert!(b[0] < 64.0 && b[1] > 64.0, "mass must move slow→fast: {b:?}");
        assert!((b[0] + b[1] - 128.0).abs() < 1e-9, "Σb broken: {b:?}");

        // Balanced observations afterwards → hold (steady state).
        for _ in 0..3 {
            ctl.observe(0, 5.0);
            ctl.observe(1, 5.0);
        }
        assert_eq!(ctl.maybe_adjust(), Adjustment::Hold);
    }

    #[test]
    fn snapshot_restore_replays_bitwise() {
        let cfg = ControllerCfg {
            min_obs: 2,
            ..ControllerCfg::default()
        };
        let mut a = RlBatcher::new(cfg.clone(), &[64.0, 64.0], RlTable::builtin());
        // Mid-interval state: one observation each, counters at 1.
        a.observe(0, 9.0);
        a.observe(1, 3.0);
        let text = a.snapshot().to_pretty();
        let j = Json::parse(&text).unwrap();
        let mut b = RlBatcher::restore(cfg, &j).unwrap();
        for round in 0..4 {
            let (ts, tf) = if round < 2 { (9.0, 3.0) } else { (5.0, 5.0) };
            a.observe(0, ts);
            a.observe(1, tf);
            b.observe(0, ts);
            b.observe(1, tf);
            assert_eq!(a.maybe_adjust(), b.maybe_adjust(), "round {round}");
            for k in 0..2 {
                assert_eq!(
                    a.inner.batch(k).to_bits(),
                    b.inner.batch(k).to_bits(),
                    "worker {k} batch diverged at round {round}"
                );
            }
        }
        assert_eq!(a.adjustments, b.adjustments);
    }

    #[test]
    fn bounded_move_respects_bounds() {
        // Full fraction admissible.
        assert!((bounded_move(100.0, 50.0, 0.25, 1.0, 4096.0) - 25.0).abs() < 1e-12);
        // Slow worker floor binds.
        assert!((bounded_move(2.0, 50.0, 0.5, 1.5, 4096.0) - 0.5).abs() < 1e-12);
        // Fast worker ceiling binds.
        assert!((bounded_move(100.0, 4090.0, 0.5, 1.0, 4096.0) - 6.0).abs() < 1e-12);
        // Nothing admissible.
        assert_eq!(bounded_move(1.0, 4096.0, 0.5, 1.0, 4096.0), 0.0);
    }

    /// Bootstrap/regeneration hook for the committed table, mirroring
    /// the scenario-golden workflow: `UPDATE_RL_TABLE=1 cargo test
    /// rl_table_regen` retrains with the canonical config and rewrites
    /// `src/controller/rl_table.json`; without the env var it only
    /// asserts the committed file is loadable.
    #[test]
    fn rl_table_regen() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("src")
            .join("controller")
            .join("rl_table.json");
        if std::env::var("UPDATE_RL_TABLE").map_or(false, |v| v == "1") {
            let table = train(&TrainCfg::default());
            std::fs::write(&path, table.to_json().to_pretty()).unwrap();
            eprintln!("rl: rewrote {}", path.display());
        } else {
            let text = std::fs::read_to_string(&path).unwrap();
            RlTable::parse(&text).unwrap();
        }
    }
}
