//! Batch-size bucket quantization (DESIGN.md §6).
//!
//! XLA artifacts have static shapes, so in real-execution mode a worker's
//! batch size must come from the AOT-compiled bucket set.  The controller
//! proposes continuous sizes; this module snaps them to buckets.  A bucket
//! *swap* rebinds a different compiled executable — the analogue of the
//! paper's TensorFlow kill-restart, and the reason the dead-band exists.

/// Snap one proposed batch size to the nearest bucket (ties prefer the
/// smaller bucket, keeping memory headroom).
pub fn quantize(proposal: f64, buckets: &[usize]) -> usize {
    assert!(!buckets.is_empty(), "no buckets");
    debug_assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets must be sorted");
    *buckets
        .iter()
        .min_by(|&&a, &&b| {
            let da = (a as f64 - proposal).abs();
            let db = (b as f64 - proposal).abs();
            da.total_cmp(&db).then(a.cmp(&b)) // tie → smaller
        })
        .unwrap()
}

/// Quantize a whole allocation. Returns (bucketed sizes, swap mask
/// relative to `current`).
pub fn quantize_alloc(
    proposals: &[f64],
    buckets: &[usize],
    current: &[usize],
) -> (Vec<usize>, Vec<bool>) {
    assert_eq!(proposals.len(), current.len());
    let snapped: Vec<usize> = proposals.iter().map(|&p| quantize(p, buckets)).collect();
    let swaps = snapped
        .iter()
        .zip(current)
        .map(|(&n, &c)| n != c)
        .collect();
    (snapped, swaps)
}

/// Quantization error as a fraction of the proposal (monitoring metric:
/// large persistent error means the bucket grid is too coarse).
pub fn quantization_error(proposal: f64, buckets: &[usize]) -> f64 {
    let q = quantize(proposal, buckets) as f64;
    if proposal == 0.0 {
        0.0
    } else {
        (q - proposal).abs() / proposal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUCKETS: [usize; 6] = [8, 16, 32, 64, 128, 256];

    #[test]
    fn snaps_to_nearest() {
        assert_eq!(quantize(10.0, &BUCKETS), 8);
        assert_eq!(quantize(13.0, &BUCKETS), 16);
        assert_eq!(quantize(100.0, &BUCKETS), 128);
        assert_eq!(quantize(90.0, &BUCKETS), 64);
    }

    #[test]
    fn clamps_to_ends() {
        assert_eq!(quantize(1.0, &BUCKETS), 8);
        assert_eq!(quantize(1e9, &BUCKETS), 256);
    }

    #[test]
    fn tie_prefers_smaller() {
        assert_eq!(quantize(12.0, &BUCKETS), 8); // equidistant 8/16
        assert_eq!(quantize(24.0, &BUCKETS), 16);
    }

    #[test]
    fn alloc_reports_swaps() {
        let (snapped, swaps) =
            quantize_alloc(&[14.0, 62.0, 250.0], &BUCKETS, &[16, 32, 256]);
        assert_eq!(snapped, vec![16, 64, 256]);
        assert_eq!(swaps, vec![false, true, false]);
    }

    #[test]
    fn error_metric() {
        assert_eq!(quantization_error(16.0, &BUCKETS), 0.0);
        assert!((quantization_error(20.0, &BUCKETS) - 0.2).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_buckets_panic() {
        quantize(1.0, &[]);
    }
}
