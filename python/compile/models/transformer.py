"""Decoder-only transformer LM — the end-to-end example workload.

Not one of the paper's three workloads, but required to prove the full
stack composes: the e2e example (``examples/e2e_train.rs``) trains this
model through the real HLO path on a heterogeneous simulated cluster and
logs the loss curve (EXPERIMENTS.md §E2E).

All dense projections (QKV, attention out, MLP, LM head) run on the Pallas
matmul kernel via 2-D reshapes; the attention score/score-apply einsums are
plain XLA (at T ≤ 256 they are a small fraction of FLOPs).  Presets:

- ``small`` (~0.8M params) — unit tests / quickstart.
- ``e2e``   (~12M params)  — the recorded end-to-end run.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul
from compile.models.common import ModelDef, ParamSpec, softmax_xent


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    vocab: int = 512
    seq: int = 64
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS = {
    "small": TransformerCfg(),
    "e2e": TransformerCfg(
        vocab=2048, seq=128, d_model=384, n_layers=6, n_heads=6
    ),
}


def _specs(cfg: TransformerCfg) -> tuple[ParamSpec, ...]:
    d = cfg.d_model
    specs = [
        ParamSpec("embed/tok", (cfg.vocab, d)),
        ParamSpec("embed/pos", (cfg.seq, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}"
        specs += [
            ParamSpec(f"{p}/ln1/g", (d,)),
            ParamSpec(f"{p}/attn/wqkv", (d, 3 * d)),
            ParamSpec(f"{p}/attn/wo", (d, d)),
            ParamSpec(f"{p}/ln2/g", (d,)),
            ParamSpec(f"{p}/mlp/w1", (d, 4 * d)),
            ParamSpec(f"{p}/mlp/b1", (4 * d,)),
            ParamSpec(f"{p}/mlp/w2", (4 * d, d)),
            ParamSpec(f"{p}/mlp/b2", (d,)),
        ]
    specs += [
        ParamSpec("lnf/g", (d,)),
        ParamSpec("head/w", (d, cfg.vocab)),
    ]
    return tuple(specs)


def _rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _mm(x2d_shape, x, w):
    """Pallas matmul over the trailing dim with a (B*T, D) reshape."""
    b, t, d = x2d_shape
    return matmul(x.reshape(b * t, d), w).reshape(b, t, w.shape[1])


def _forward(cfg: TransformerCfg, params, tokens):
    it = iter(params)
    b, t = tokens.shape
    tok, pos = next(it), next(it)
    h = tok[tokens] + pos[None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    for _ in range(cfg.n_layers):
        g1, wqkv, wo, g2, w1, b1, w2, b2 = (next(it) for _ in range(8))
        # --- attention ---
        x = _rmsnorm(h, g1)
        qkv = _mm((b, t, cfg.d_model), x, wqkv)
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):
            return z.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(cfg.head_dim))
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        h = h + _mm((b, t, cfg.d_model), ctx, wo)
        # --- mlp ---
        x = _rmsnorm(h, g2)
        x = jax.nn.gelu(_mm((b, t, cfg.d_model), x, w1) + b1)
        h = h + _mm((b, t, 4 * cfg.d_model), x, w2) + b2
    h = _rmsnorm(h, next(it))
    return _mm((b, t, cfg.d_model), h, next(it))  # (b, t, vocab)


def transformer_def(preset: str = "small") -> ModelDef:
    cfg = PRESETS[preset]

    def loss_fn(params, x, y):
        logits = _forward(cfg, params, x)
        return softmax_xent(logits, y)

    def metric_fn(params, x, y):
        logits = _forward(cfg, params, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    # init for g (norm gains) should be ones, not zeros — override init via
    # spec naming convention handled in init_params_transformer below.
    return ModelDef(
        name="transformer" if preset == "small" else f"transformer_{preset}",
        param_specs=_specs(cfg),
        loss_fn=loss_fn,
        metric_fn=metric_fn,
        x_shape=(cfg.seq,),
        x_dtype="i32",
        y_shape=(cfg.seq,),
        y_dtype="i32",
        task="lm",
        default_buckets=(2, 4, 8, 16),
    )


def init_params(model: ModelDef, seed: int = 0) -> list[jax.Array]:
    """Transformer-aware init: norm gains start at 1, embeds at N(0, 0.02)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for spec in model.param_specs:
        key, sub = jax.random.split(key)
        if spec.name.endswith("/g"):
            params.append(jnp.ones(spec.shape, jnp.float32))
        elif spec.name.startswith("embed/"):
            params.append(0.02 * jax.random.normal(sub, spec.shape, jnp.float32))
        elif len(spec.shape) >= 2:
            scale = jnp.sqrt(2.0 / spec.shape[0])
            params.append(scale * jax.random.normal(sub, spec.shape, jnp.float32))
        else:
            params.append(jnp.zeros(spec.shape, jnp.float32))
    return params


TRANSFORMER = transformer_def("small")
