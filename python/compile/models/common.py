"""Shared model plumbing: flat named parameters, layers, losses.

Parameters are kept as a *flat ordered list* of named arrays rather than a
pytree: the AOT boundary (HLO text) has positional arguments only, and the
Rust parameter server addresses tensors by index.  ``ParamSpec`` carries the
name/shape so the manifest can describe the layout to the Rust side.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A model as the AOT pipeline sees it.

    loss_fn(params, x, y) -> scalar mean loss over the mini-batch.
    metric_fn(params, x, y) -> auxiliary eval scalar (accuracy / mse).
    """

    name: str
    param_specs: tuple[ParamSpec, ...]
    loss_fn: Callable[[Sequence[jax.Array], jax.Array, jax.Array], jax.Array]
    metric_fn: Callable[[Sequence[jax.Array], jax.Array, jax.Array], jax.Array]
    x_shape: tuple[int, ...]  # per-example input shape
    x_dtype: str  # "f32" | "i32"
    y_shape: tuple[int, ...]  # per-example label shape
    y_dtype: str
    task: str  # "classification" | "regression" | "lm"
    default_buckets: tuple[int, ...]

    def init_params(self, seed: int = 0) -> list[jax.Array]:
        """He-style init, deterministic from seed, matching param_specs."""
        key = jax.random.PRNGKey(seed)
        params = []
        for spec in self.param_specs:
            key, sub = jax.random.split(key)
            if len(spec.shape) >= 2:
                fan_in = 1
                for s in spec.shape[:-1]:
                    fan_in *= s
                scale = jnp.sqrt(2.0 / fan_in)
                params.append(
                    scale * jax.random.normal(sub, spec.shape, jnp.float32)
                )
            else:
                params.append(jnp.zeros(spec.shape, jnp.float32))
        return params

    def train_step(self, params: Sequence[jax.Array], x: jax.Array, y: jax.Array):
        """(loss, *grads) — the function AOT lowers per batch bucket."""
        loss, grads = jax.value_and_grad(
            lambda p: self.loss_fn(p, x, y)
        )(list(params))
        return (loss, *grads)

    def eval_step(self, params: Sequence[jax.Array], x: jax.Array, y: jax.Array):
        """(loss, metric) for held-out evaluation."""
        return (self.loss_fn(list(params), x, y), self.metric_fn(list(params), x, y))


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Dense layer on the Pallas matmul kernel."""
    return matmul(x, w) + b


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; labels are int class ids."""
    logits = logits - jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
    nll = -jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def mse(pred: jax.Array, target: jax.Array) -> jax.Array:
    return jnp.mean((pred - target) ** 2)
