"""L2 model zoo: the paper's three workloads plus an e2e transformer.

Each model is a :class:`compile.models.common.ModelDef` exposing
``init_params`` / ``loss_fn`` over a flat, ordered parameter list so that
``aot.py`` can lower ``train_step(params..., x, y) -> (loss, *grads)`` and
the Rust runtime can address parameters positionally.

Registry keys mirror the paper's workloads:

- ``linreg``      — Linear Regression (bar-crawl stand-in; paper §IV).
- ``mlp``         — MNIST CNN stand-in: dense ReLU net on 784-dim inputs.
- ``cnn``         — ResNet-50/CIFAR-10 stand-in: residual conv net, 32x32x3.
- ``transformer`` — decoder-only LM for the end-to-end example.
"""

from __future__ import annotations

from compile.models.common import ModelDef
from compile.models.linreg import LINREG
from compile.models.mlp import MLP
from compile.models.cnn import CNN
from compile.models.transformer import TRANSFORMER, transformer_def

REGISTRY: dict[str, ModelDef] = {
    "linreg": LINREG,
    "mlp": MLP,
    "cnn": CNN,
    "transformer": TRANSFORMER,
}


def get_model(name: str) -> ModelDef:
    """Look up a model by registry name (raises KeyError with choices)."""
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown model {name!r}; choices: {sorted(REGISTRY)}")
