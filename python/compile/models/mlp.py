"""MNIST-CNN stand-in: dense ReLU classifier on 784-dim inputs.

The paper's second workload is the TF official MNIST CNN trained with
Adam(1e-4).  The conv stem of that net is a fixed feature extractor at this
scale; what the batching controller sees is a medium-FLOPs classification
step.  We reproduce it as a 784-256-128-10 MLP whose dense layers run on
the Pallas matmul kernel — same loss (softmax CE), same optimizer, matched
compute class (lighter than the CNN, far heavier than LR).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.models.common import (
    ModelDef,
    ParamSpec,
    accuracy,
    dense,
    softmax_xent,
)

IN_DIM = 784
HIDDEN = (256, 128)
CLASSES = 10

_SPECS = (
    ParamSpec("fc1/w", (IN_DIM, HIDDEN[0])),
    ParamSpec("fc1/b", (HIDDEN[0],)),
    ParamSpec("fc2/w", (HIDDEN[0], HIDDEN[1])),
    ParamSpec("fc2/b", (HIDDEN[1],)),
    ParamSpec("head/w", (HIDDEN[1], CLASSES)),
    ParamSpec("head/b", (CLASSES,)),
)


def _logits(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = jax.nn.relu(dense(x, w1, b1))
    h = jax.nn.relu(dense(h, w2, b2))
    return dense(h, w3, b3)


def _loss(params, x, y):
    return softmax_xent(_logits(params, x), y)


def _metric(params, x, y):
    return accuracy(_logits(params, x), y)


MLP = ModelDef(
    name="mlp",
    param_specs=_SPECS,
    loss_fn=_loss,
    metric_fn=_metric,
    x_shape=(IN_DIM,),
    x_dtype="f32",
    y_shape=(),
    y_dtype="i32",
    task="classification",
    default_buckets=(8, 16, 32, 64, 128, 256),
)
