"""ResNet/CIFAR-10 stand-in: residual conv net on 32x32x3 inputs.

The paper's heaviest workload is TF's ResNet benchmark on CIFAR-10 with a
momentum optimizer.  A faithful-depth ResNet-50 cannot be trained to target
accuracy inside this testbed's budget, so we keep the *architecture family*
(conv stem -> residual blocks with stride-2 stage transitions -> global
average pool -> dense head) at reduced width/depth; the dense head runs on
the Pallas matmul kernel.  Where the paper's evaluation needs full-ResNet
*timing*, the capacity model is calibrated on FLOPs instead (see
rust ``cluster::capacity``); this net provides the real-gradient path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from compile.models.common import (
    ModelDef,
    ParamSpec,
    accuracy,
    dense,
    softmax_xent,
)

CLASSES = 10
STEM = 16
STAGES = (16, 32)  # one residual block per stage; stage i>0 downsamples


def _conv_specs() -> tuple[ParamSpec, ...]:
    specs = [ParamSpec("stem/k", (3, 3, 3, STEM))]
    cin = STEM
    for i, cout in enumerate(STAGES):
        specs.append(ParamSpec(f"block{i}/conv1/k", (3, 3, cin, cout)))
        specs.append(ParamSpec(f"block{i}/conv2/k", (3, 3, cout, cout)))
        if cin != cout:
            specs.append(ParamSpec(f"block{i}/proj/k", (1, 1, cin, cout)))
        cin = cout
    specs.append(ParamSpec("head/w", (STAGES[-1], CLASSES)))
    specs.append(ParamSpec("head/b", (CLASSES,)))
    return tuple(specs)


_SPECS = _conv_specs()


def _conv(x, k, stride=1):
    return lax.conv_general_dilated(
        x,
        k,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _forward(params, x):
    it = iter(params)
    h = jax.nn.relu(_conv(x, next(it)))
    cin = STEM
    for i, cout in enumerate(STAGES):
        stride = 1 if i == 0 else 2
        k1, k2 = next(it), next(it)
        r = jax.nn.relu(_conv(h, k1, stride))
        r = _conv(r, k2)
        if cin != cout:
            h = _conv(h, next(it), stride)
        h = jax.nn.relu(h + r)
        cin = cout
    h = jnp.mean(h, axis=(1, 2))  # global average pool -> (B, C)
    w, b = next(it), next(it)
    return dense(h, w, b)


def _loss(params, x, y):
    return softmax_xent(_forward(params, x), y)


def _metric(params, x, y):
    return accuracy(_forward(params, x), y)


CNN = ModelDef(
    name="cnn",
    param_specs=_SPECS,
    loss_fn=_loss,
    metric_fn=_metric,
    x_shape=(32, 32, 3),
    x_dtype="f32",
    y_shape=(),
    y_dtype="i32",
    task="classification",
    default_buckets=(4, 8, 16, 32, 64),
)
