"""Linear Regression — the paper's lightest workload (bar-crawl stand-in).

The paper runs LR on Harvard's bar-crawl accelerometer dataset (3 features
-> 1 TAC target).  We keep the 3-feature shape; data is synthetic with a
fixed ground-truth weight vector (see rust ``data::synth_regression``) so
the loss floor is known.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.models.common import ModelDef, ParamSpec, dense, mse

IN_DIM = 3

_SPECS = (
    ParamSpec("linear/w", (IN_DIM, 1)),
    ParamSpec("linear/b", (1,)),
)


def _predict(params, x):
    w, b = params
    return dense(x, w, b)


def _loss(params, x, y):
    return mse(_predict(params, x), y)


def _metric(params, x, y):
    # For regression the eval metric is the MSE itself.
    return mse(_predict(params, x), y)


LINREG = ModelDef(
    name="linreg",
    param_specs=_SPECS,
    loss_fn=_loss,
    metric_fn=_metric,
    x_shape=(IN_DIM,),
    x_dtype="f32",
    y_shape=(1,),
    y_dtype="f32",
    task="regression",
    default_buckets=(8, 16, 32, 64, 128, 256, 512),
)
