"""AOT pipeline: lower every (model, batch-bucket) train/eval step to HLO
text and emit the artifact manifest the Rust runtime loads.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/gen_hlo.py.

Per-worker batch sizes are *dynamic* at the coordination layer but XLA
shapes are static, so each model is lowered once per batch-size bucket;
the Rust controller quantizes controller proposals to the bucket grid and
swaps executables (DESIGN.md §6 — this plays the role of the paper's TF
kill-restart cost).

Outputs (under --out-dir, default ../artifacts):
  <model>_train_b<B>.hlo.txt     train_step(params..., x, y) -> (loss, *grads)
  <model>_eval_b<B>.hlo.txt      eval_step(params..., x, y)  -> (loss, metric)
  <model>_init.bin               f32-LE concatenation of initial params
  grad_agg_k<K>.hlo.txt          PS-side fused weighted aggregation kernel
  manifest.json                  index of everything above
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.kernels.grad_agg import weighted_agg
from compile.models import REGISTRY, get_model
from compile.models import transformer as tr
from compile.models.common import ModelDef

# Fixed chunk width for the PS-side aggregation artifact; Rust walks the
# flattened parameter vector in chunks of this size (zero-padding the tail).
AGG_CHUNK = 1 << 20
AGG_KS = (2, 3, 4)

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_args(model: ModelDef, batch: int):
    params = [
        jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.param_specs
    ]
    x = jax.ShapeDtypeStruct((batch, *model.x_shape), DTYPES[model.x_dtype])
    y = jax.ShapeDtypeStruct((batch, *model.y_shape), DTYPES[model.y_dtype])
    return params, x, y


def lower_model_step(model: ModelDef, batch: int, kind: str) -> str:
    params, x, y = example_args(model, batch)
    fn = model.train_step if kind == "train" else model.eval_step

    def flat(*args):
        return fn(list(args[: len(params)]), args[-2], args[-1])

    lowered = jax.jit(flat).lower(*params, x, y)
    return to_hlo_text(lowered)


def lower_grad_agg(k: int, d: int = AGG_CHUNK) -> str:
    lam = jax.ShapeDtypeStruct((k,), jnp.float32)
    grads = jax.ShapeDtypeStruct((k, d), jnp.float32)
    lowered = jax.jit(lambda l, g: (weighted_agg(l, g),)).lower(lam, grads)
    return to_hlo_text(lowered)


def init_param_bytes(model: ModelDef, seed: int) -> bytes:
    if model.task == "lm":
        params = tr.init_params(model, seed)
    else:
        params = model.init_params(seed)
    return b"".join(
        np.asarray(p, dtype="<f4").tobytes(order="C") for p in params
    )


def write_if_changed(path: str, data) -> bool:
    """Write text/bytes only when content differs (keeps `make` idempotent)."""
    mode = "wb" if isinstance(data, bytes) else "w"
    if os.path.exists(path):
        with open(path, "rb") as f:
            old = f.read()
        new = data if isinstance(data, bytes) else data.encode()
        if old == new:
            return False
    with open(path, mode) as f:
        f.write(data)
    return True


def build(out_dir: str, model_names: list[str], seed: int, quiet: bool) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"version": 1, "models": {}, "agg": {}}

    for name in model_names:
        model = get_model(name)
        entry = {
            "params": [
                {"name": s.name, "shape": list(s.shape)}
                for s in model.param_specs
            ],
            "param_total": sum(s.size for s in model.param_specs),
            "x_shape": list(model.x_shape),
            "x_dtype": model.x_dtype,
            "y_shape": list(model.y_shape),
            "y_dtype": model.y_dtype,
            "task": model.task,
            "buckets": sorted(model.default_buckets),
            "train": {},
            "eval": {},
            "init": f"{name}_init.bin",
        }
        for b in entry["buckets"]:
            for kind in ("train", "eval"):
                fname = f"{name}_{kind}_b{b}.hlo.txt"
                text = lower_model_step(model, b, kind)
                changed = write_if_changed(os.path.join(out_dir, fname), text)
                entry[kind][str(b)] = fname
                if not quiet:
                    state = "wrote" if changed else "up-to-date"
                    print(f"  {state} {fname} ({len(text) // 1024} KiB)")
        write_if_changed(
            os.path.join(out_dir, entry["init"]), init_param_bytes(model, seed)
        )
        manifest["models"][name] = entry

    for k in AGG_KS:
        fname = f"grad_agg_k{k}.hlo.txt"
        write_if_changed(os.path.join(out_dir, fname), lower_grad_agg(k))
        manifest["agg"][str(k)] = fname
        if not quiet:
            print(f"  wrote {fname}")
    manifest["agg_chunk"] = AGG_CHUNK

    write_if_changed(
        os.path.join(out_dir, "manifest.json"),
        json.dumps(manifest, indent=2, sort_keys=True),
    )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="linreg,mlp,cnn,transformer",
        help="comma-separated registry names (see compile.models.REGISTRY)",
    )
    ap.add_argument(
        "--e2e",
        action="store_true",
        help="also lower the ~12M-param e2e transformer preset",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    # Back-compat with the Makefile's original `--out artifacts/model.hlo.txt`.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."

    names = [n for n in args.models.split(",") if n]
    if args.e2e:
        REGISTRY["transformer_e2e"] = tr.transformer_def("e2e")
        names.append("transformer_e2e")

    manifest = build(out_dir, names, args.seed, args.quiet)
    n_art = sum(
        len(m["train"]) + len(m["eval"]) for m in manifest["models"].values()
    ) + len(manifest["agg"])
    print(f"aot: {n_art} artifacts in {out_dir}")
    # Marker file the Makefile can depend on.
    write_if_changed(os.path.join(out_dir, "model.hlo.txt"), "# see manifest.json\n")


if __name__ == "__main__":
    main()
