"""Back-compat shim: the model zoo lives in :mod:`compile.models`."""

from compile.models import REGISTRY, get_model  # noqa: F401
