"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest (``python/tests``) asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-driven shape and
dtype sweeps before any artifact is trusted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul."""
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def weighted_agg_ref(lam: jax.Array, grads: jax.Array) -> jax.Array:
    """out[j] = Σ_k lam[k]·grads[k, j]."""
    return jnp.einsum(
        "k,kd->d", lam.astype(jnp.float32), grads.astype(jnp.float32)
    )
