"""Fused λ-weighted gradient aggregation — the parameter-server hot-spot.

Paper Eq. 2–3: the PS computes  g = Σ_k λ_k · ∇f(x_{b_k})  with
λ_k = b_k / Σ_i b_i, so workers with larger mini-batches contribute
proportionally more.  Materializing K scaled copies wastes memory
bandwidth; this kernel fuses scale+reduce in a single pass.

Layout: gradients are flattened and stacked into G[K, D]; λ is a (K, 1)
column.  The 1-D grid walks D in ``bd``-wide chunks, each step loading a
(K, bd) tile and the full λ column into VMEM and writing one (bd,) output
chunk:  out[j] = Σ_k λ[k]·G[k, j].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk width: K·bd·4 bytes of VMEM per step; for K ≤ 16 a 16 Ki chunk
# keeps the tile ≤ 1 MiB and the reduction bandwidth-bound (as it should
# be — there is one multiply-add per loaded element).
BD = 16 * 1024


def _agg_kernel(lam_ref, g_ref, o_ref):
    # (K, bd) * (K, 1) -> sum over K -> (bd,)
    o_ref[...] = jnp.sum(g_ref[...] * lam_ref[...], axis=0)


def weighted_agg_unchecked(lam: jax.Array, grads: jax.Array, *, bd: int = BD) -> jax.Array:
    """Aggregate for D already a multiple of ``bd``. lam: (K,1), grads: (K,D)."""
    k, d = grads.shape
    assert lam.shape == (k, 1), (lam.shape, grads.shape)
    assert d % bd == 0, (d, bd)
    return pl.pallas_call(
        _agg_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, bd), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(lam, grads)


def weighted_agg(lam: jax.Array, grads: jax.Array, *, bd: int = BD) -> jax.Array:
    """out[j] = Σ_k lam[k]·grads[k, j], padding D up to the chunk width.

    lam: (K,) weights (the caller normalizes Σλ = 1); grads: (K, D).
    """
    k, d = grads.shape
    bd = min(bd, max(128, 1 << (d - 1).bit_length()))  # don't over-pad tiny D
    dp = (d + bd - 1) // bd * bd
    gp = grads if dp == d else jnp.pad(grads, ((0, 0), (0, dp - d)))
    out = weighted_agg_unchecked(lam.reshape(k, 1).astype(jnp.float32), gp, bd=bd)
    return out[:d]
