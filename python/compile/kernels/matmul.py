"""Block-tiled Pallas matmul — the L1 compute hot-spot.

The paper's training hot-spot (dense/conv FLOPs) maps on TPU-shaped
hardware to an MXU-tiled matmul: the grid walks (M/bm, N/bn, K/bk) blocks,
each step bringing one (bm, bk) x-tile and one (bk, bn) w-tile from HBM
into VMEM (expressed via BlockSpec index maps) and accumulating into the
(bm, bn) output tile, which is revisited across the K dimension.

Lowered with ``interpret=True`` so the resulting HLO runs on any PJRT
backend (CPU here); on a real TPU the same kernel compiles to Mosaic.

Block shapes default to multiples of the (8, 128) TPU register tile; the
128x128 MXU is fully occupied when bm, bn >= 128.  VMEM footprint per grid
step = (bm*bk + bk*bn + bm*bn) * 4 bytes — see DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: a grid step's working set (3 tiles, f32) is
# 3*256*256*4 = 768 KiB << 16 MiB VMEM, each tile a whole multiple of the
# 128x128 MXU shape. 256 over 128 measured -35% wall on the CPU-interpret
# path (fewer grid steps => less interpreter loop overhead) with identical
# numerics — see EXPERIMENTS.md §Perf L1.
BM, BK, BN = 256, 256, 256


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One grid step: accumulate x_tile @ w_tile into the output tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul_unchecked(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
) -> jax.Array:
    """Pallas matmul for shapes already padded to tile multiples."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (x.shape, w.shape)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _pick_block(dim: int, pref: int) -> int:
    """Shrink the preferred tile for small dims (still a multiple of 8)."""
    if dim >= pref:
        return pref
    return max(8, _ceil_to(dim, 8))


def _matmul_impl(x: jax.Array, w: jax.Array) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    bm = _pick_block(m, BM)
    bk = _pick_block(k, BK)
    bn = _pick_block(n, BN)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = x if (mp == m and kp == k) else jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = w if (kp == k and np_ == n) else jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = matmul_unchecked(xp, wp, bm=bm, bk=bk, bn=bn)
    if mp != m or np_ != n:
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """``x @ w`` with the Pallas kernel on both forward and backward paths.

    ``pallas_call`` has no transpose rule, so the VJP is defined explicitly:
    dx = g @ w^T and dw = x^T @ g, each itself a Pallas matmul — the whole
    fwd+bwd graph lowers to the tiled kernel.
    """
    return _matmul_impl(x, w)


def _matmul_fwd(x, w):
    return _matmul_impl(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = _matmul_impl(g, w.T)
    dw = _matmul_impl(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
