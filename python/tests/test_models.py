"""L2 model checks: shapes, gradient correctness, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import REGISTRY, get_model
from compile.models import transformer as tr
from compile.models.common import ModelDef

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def _batch(model: ModelDef, b: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    if model.x_dtype == "f32":
        x = jax.random.normal(kx, (b, *model.x_shape), jnp.float32)
    else:
        x = jax.random.randint(kx, (b, *model.x_shape), 0, 64)
    if model.task == "regression":
        y = jax.random.normal(ky, (b, *model.y_shape), jnp.float32)
    elif model.task == "lm":
        y = jax.random.randint(ky, (b, *model.y_shape), 0, 64)
    else:
        y = jax.random.randint(ky, (b, *model.y_shape), 0, 10)
    return x, y


def _params(model: ModelDef, seed: int = 0):
    if model.task == "lm":
        return tr.init_params(model, seed)
    return model.init_params(seed)


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_init_matches_specs(name):
    model = get_model(name)
    params = _params(model)
    assert len(params) == len(model.param_specs)
    for p, spec in zip(params, model.param_specs):
        assert p.shape == spec.shape, spec.name
        assert p.dtype == jnp.float32


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_train_step_shapes_and_finiteness(name):
    model = get_model(name)
    params = _params(model)
    x, y = _batch(model, 4)
    out = model.train_step(params, x, y)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    assert len(grads) == len(params)
    for g, spec in zip(grads, model.param_specs):
        assert g.shape == spec.shape, spec.name
        assert np.all(np.isfinite(np.asarray(g))), spec.name


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_eval_step(name):
    model = get_model(name)
    loss, metric = model.eval_step(_params(model), *_batch(model, 4))
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metric))


def test_linreg_grads_match_numeric():
    """Analytic check on the simplest model: dL/dw = 2/b · X^T (Xw+b − y)."""
    model = get_model("linreg")
    params = _params(model)
    x, y = _batch(model, 16)
    _, gw, gb = model.train_step(params, x, y)
    w, b = params
    resid = x @ w + b - y
    np.testing.assert_allclose(
        gw, 2.0 / 16 * x.T @ resid, rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        gb, 2.0 * jnp.mean(resid, axis=0), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("name", ["linreg", "mlp", "transformer"])
def test_sgd_reduces_loss(name):
    model = get_model(name)
    params = _params(model)
    x, y = _batch(model, 16)
    lr = 0.05 if name == "linreg" else 0.1
    first = None
    for _ in range(10):
        out = model.train_step(params, x, y)
        loss, grads = float(out[0]), out[1:]
        if first is None:
            first = loss
        params = [p - lr * g for p, g in zip(params, grads)]
    last = float(model.loss_fn(params, x, y))
    assert last < first, (first, last)


def test_classification_loss_at_init_is_log_classes():
    model = get_model("mlp")
    # He-init logits have O(1) spread, so CE sits near (not at) ln(10).
    loss = float(model.loss_fn(_params(model), *_batch(model, 32)))
    assert abs(loss - np.log(10)) < 1.5


def test_transformer_causality():
    """Changing token t must not change logits at positions < t."""
    model = get_model("transformer")
    params = tr.init_params(model, 0)
    cfg = tr.PRESETS["small"]
    x = jax.random.randint(jax.random.PRNGKey(0), (1, cfg.seq), 0, cfg.vocab)
    logits_a = tr._forward(cfg, params, x)
    x2 = x.at[0, cfg.seq - 1].set((x[0, cfg.seq - 1] + 1) % cfg.vocab)
    logits_b = tr._forward(cfg, params, x2)
    np.testing.assert_allclose(
        logits_a[0, : cfg.seq - 1], logits_b[0, : cfg.seq - 1], atol=1e-5
    )
    assert not np.allclose(logits_a[0, -1], logits_b[0, -1])


def test_e2e_preset_param_count():
    model = tr.transformer_def("e2e")
    total = sum(s.size for s in model.param_specs)
    assert 10_000_000 < total < 20_000_000, total


def test_gradient_scale_invariance_under_batch_growth():
    """Mean-loss gradients must be O(1) in batch size — the PS relies on
    per-example-mean semantics when λ-weighting different b_k (Eq. 2)."""
    model = get_model("mlp")
    params = _params(model)
    x, y = _batch(model, 64)
    g8 = model.train_step(params, x[:8], y[:8])[1]
    g64 = model.train_step(params, x, y)[1]
    n8 = float(jnp.linalg.norm(g8))
    n64 = float(jnp.linalg.norm(g64))
    assert 0.2 < n8 / n64 < 5.0
