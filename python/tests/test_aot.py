"""AOT pipeline checks: HLO text validity, manifest schema, init blobs."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.models import get_model


def test_hlo_text_smells_like_hlo():
    text = aot.lower_model_step(get_model("linreg"), 8, "train")
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: root must be a tuple of (loss, grad_w, grad_b).
    assert "(f32[], f32[3,1]" in text.replace(" ", "")[:10_000] or "tuple(" in text


def _entry_param_count(text: str) -> int:
    entry = text[text.index("ENTRY") :]
    return sum(
        1 for line in entry.splitlines() if " parameter(" in line
    )


def test_train_hlo_has_param_count_outputs():
    model = get_model("mlp")
    text = aot.lower_model_step(model, 8, "train")
    # 6 params + x + y = 8 inputs
    assert _entry_param_count(text) == 8


def test_eval_hlo_two_outputs():
    text = aot.lower_model_step(get_model("mlp"), 8, "eval")
    assert text.startswith("HloModule")


def test_grad_agg_hlo():
    text = aot.lower_grad_agg(3)
    assert text.startswith("HloModule")
    assert _entry_param_count(text) == 2


def test_init_param_bytes_length():
    model = get_model("mlp")
    blob = aot.init_param_bytes(model, 0)
    total = sum(s.size for s in model.param_specs)
    assert len(blob) == 4 * total


def test_init_param_bytes_deterministic_and_seeded():
    model = get_model("linreg")
    assert aot.init_param_bytes(model, 0) == aot.init_param_bytes(model, 0)
    assert aot.init_param_bytes(model, 0) != aot.init_param_bytes(model, 1)


def test_transformer_init_norm_gains_are_one():
    model = get_model("transformer")
    blob = aot.init_param_bytes(model, 0)
    arr = np.frombuffer(blob, dtype="<f4")
    off = 0
    for spec in model.param_specs:
        if spec.name.endswith("/g"):
            chunk = arr[off : off + spec.size]
            assert np.all(chunk == 1.0), spec.name
        off += spec.size
    assert off == len(arr)


def test_write_if_changed(tmp_path):
    p = str(tmp_path / "f.txt")
    assert aot.write_if_changed(p, "hello")
    assert not aot.write_if_changed(p, "hello")
    assert aot.write_if_changed(p, "world")


def test_build_manifest_schema(tmp_path):
    manifest = aot.build(str(tmp_path), ["linreg"], seed=0, quiet=True)
    m = manifest["models"]["linreg"]
    assert m["param_total"] == 4
    assert m["task"] == "regression"
    for b in m["buckets"]:
        assert os.path.exists(tmp_path / m["train"][str(b)])
        assert os.path.exists(tmp_path / m["eval"][str(b)])
    assert os.path.exists(tmp_path / m["init"])
    for k, fname in manifest["agg"].items():
        assert os.path.exists(tmp_path / fname)
    # manifest.json parses back
    with open(tmp_path / "manifest.json") as f:
        assert json.load(f)["version"] == 1
