"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes (including non-tile-multiple and degenerate ones)
and asserts the Pallas kernels match the pure-jnp oracles in ``ref.py``.
Every artifact the Rust runtime executes embeds these kernels, so this
suite gates `make artifacts`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.grad_agg import weighted_agg, weighted_agg_unchecked
from compile.kernels.matmul import matmul, matmul_unchecked
from compile.kernels.ref import matmul_ref, weighted_agg_ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul


@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-3
    )


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),  # exactly one tile
        (256, 384, 128),  # multi-tile all dims
        (8, 8, 8),  # minimum tile
        (1, 1, 1),  # degenerate, fully padded
        (3, 1000, 5),  # long-K reduction
        (137, 61, 251),  # coprime everything
    ],
)
def test_matmul_shape_grid(m, k, n):
    x = _rand(7, (m, k))
    w = _rand(8, (k, n))
    np.testing.assert_allclose(
        matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-3
    )


def test_matmul_unchecked_requires_tile_multiples():
    x = _rand(0, (100, 128))
    w = _rand(1, (128, 128))
    with pytest.raises(AssertionError):
        matmul_unchecked(x, w)


@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_custom_vjp_matches_ref_grads(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))

    def f(mm):
        return lambda a, b: jnp.sum(jnp.tanh(mm(a, b)))

    gx, gw = jax.grad(f(matmul), argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f(matmul_ref), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


def test_matmul_zero_input_gives_zero():
    out = matmul(jnp.zeros((16, 32)), _rand(0, (32, 16)))
    assert not np.any(np.asarray(out))


def test_matmul_identity():
    x = _rand(3, (64, 64))
    np.testing.assert_allclose(
        matmul(x, jnp.eye(64)), x, rtol=1e-6, atol=1e-6
    )


def test_matmul_jittable():
    x, w = _rand(0, (40, 24)), _rand(1, (24, 56))
    np.testing.assert_allclose(
        jax.jit(matmul)(x, w), matmul_ref(x, w), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------- grad_agg


@given(
    k=st.integers(1, 8),
    d=st.integers(1, 5000),
    seed=st.integers(0, 2**31 - 1),
)
def test_weighted_agg_matches_ref(k, d, seed):
    g = _rand(seed, (k, d))
    lam = jax.nn.softmax(_rand(seed + 1, (k,)))
    np.testing.assert_allclose(
        weighted_agg(lam, g), weighted_agg_ref(lam, g), rtol=1e-5, atol=1e-5
    )


def test_weighted_agg_uniform_lambda_is_mean():
    """With λ_k = 1/K the paper's Eq. 2–3 reduce to plain averaging."""
    k, d = 4, 1024
    g = _rand(0, (k, d))
    lam = jnp.full((k,), 1.0 / k)
    np.testing.assert_allclose(
        weighted_agg(lam, g), jnp.mean(g, axis=0), rtol=1e-5, atol=1e-6
    )


def test_weighted_agg_single_worker_identity():
    g = _rand(0, (1, 777))
    np.testing.assert_allclose(
        weighted_agg(jnp.ones(1), g), g[0], rtol=1e-6, atol=1e-7
    )


def test_weighted_agg_linear_in_lambda():
    """agg(αλ1 + βλ2) == α·agg(λ1) + β·agg(λ2) — required for the PS to
    renormalize λ without re-reading gradients."""
    k, d = 3, 512
    g = _rand(0, (k, d))
    l1 = jax.nn.softmax(_rand(1, (k,)))
    l2 = jax.nn.softmax(_rand(2, (k,)))
    lhs = weighted_agg(0.3 * l1 + 0.7 * l2, g)
    rhs = 0.3 * weighted_agg(l1, g) + 0.7 * weighted_agg(l2, g)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-6)


def test_weighted_agg_unchecked_requires_chunk_multiple():
    g = _rand(0, (2, 100))
    with pytest.raises(AssertionError):
        weighted_agg_unchecked(jnp.ones((2, 1)), g, bd=64)


def test_weighted_agg_exact_chunk_multiple_unpadded():
    g = _rand(0, (2, 256))
    lam = jnp.asarray([0.25, 0.75])
    out = weighted_agg(lam, g, bd=128)
    np.testing.assert_allclose(out, weighted_agg_ref(lam, g), rtol=1e-5, atol=1e-6)
