#!/usr/bin/env bash
# Tier-1 gate (see ROADMAP.md) + hot-path bench smoke.
#
#   build --release  →  test -q  →  quick aggregation-only hotpath bench
#
# The bench smoke runs with --agg-only (no PJRT artifacts needed) and
# HBATCH_BENCH_QUICK=1 (short measurement windows); partial/quick runs
# write BENCH_hotpath_quick.json so they never clobber the canonical
# BENCH_hotpath.json, which only a full `cargo bench --bench hotpath`
# (no flags) refreshes.
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not on PATH — install the rust toolchain first" >&2
    exit 1
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

echo "== tier1: hotpath bench smoke (agg only, quick) =="
HBATCH_BENCH_QUICK=1 cargo bench --bench hotpath -- --agg-only

echo "tier1: OK"
