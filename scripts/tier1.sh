#!/usr/bin/env bash
# Tier-1 gate (see ROADMAP.md) + hot-path bench smoke.
#
#   build --release  →  test -q  →  quick aggregation-only hotpath bench
#   →  session/fleet bench smokes  →  CLI smokes (fault recovery, batch
#   policies, crash → resume bit-identity)
#
# The bench smoke runs with --agg-only (no PJRT artifacts needed) and
# HBATCH_BENCH_QUICK=1 (short measurement windows); partial/quick runs
# write BENCH_hotpath_quick.json so they never clobber the canonical
# BENCH_hotpath.json, which only a full `cargo bench --bench hotpath`
# (no flags) refreshes.  The session-loop suite gets the same treatment:
# the smoke runs it truncated to k <= 64 (BENCH_session_quick.json); the
# canonical BENCH_session.json comes from a full `cargo bench --bench
# session`.  Likewise the fleet suite: the smoke runs 32 jobs at k <= 8
# (BENCH_fleet_quick.json); the canonical BENCH_fleet.json comes from a
# full `cargo bench --bench fleet` (1000 jobs + k = 512 fleets).
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not on PATH — install the rust toolchain first" >&2
    exit 1
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test -q =="
cargo test -q

echo "== tier1: hotpath bench smoke (agg only, quick) =="
HBATCH_BENCH_QUICK=1 cargo bench --bench hotpath -- --agg-only

# The eager-reduction-tree series (PR 5) must be present in the smoke
# artifact — a silent disappearance of the tree_vs_flat derived ratios
# would mean the canonical bench regenerates without the acceptance
# series.
if ! grep -q 'tree_vs_flat' ../BENCH_hotpath_quick.json; then
    echo "tier1: BENCH_hotpath_quick.json is missing the tree_vs_flat series" >&2
    exit 1
fi

echo "== tier1: session bench smoke (k <= 64, quick) =="
# Truncated grid + quick windows => writes BENCH_session_quick.json,
# never the canonical BENCH_session.json (full `cargo bench --bench
# session` only).  Also self-checks heap vs scan report identity.
HBATCH_BENCH_QUICK=1 cargo bench --bench session -- --max-k 64

# The policy head-to-head series (PR 8) must be present in the session
# smoke artifact — a silent disappearance would mean the canonical
# bench regenerates without the pid/optimal/rl comparison.
if ! grep -q 'policy_head2head' ../BENCH_session_quick.json; then
    echo "tier1: BENCH_session_quick.json is missing the policy_head2head series" >&2
    exit 1
fi

echo "== tier1: fleet bench smoke (32 jobs, k <= 8, quick) =="
# Truncated fleet + quick windows => writes BENCH_fleet_quick.json,
# never the canonical BENCH_fleet.json (full `cargo bench --bench
# fleet` only).  The bench self-asserts the isolation invariant
# (fleet-run reports bitwise-identical to standalone) before timing.
HBATCH_BENCH_QUICK=1 cargo bench --bench fleet -- --jobs 32 --max-k 8

# The per-job overhead series is the fleet acceptance artifact — its
# silent disappearance would mean the canonical bench regenerates
# without the sublinearity evidence.
if ! grep -q 'overhead_per_job' ../BENCH_fleet_quick.json; then
    echo "tier1: BENCH_fleet_quick.json is missing the overhead_per_job series" >&2
    exit 1
fi

echo "== tier1: fault-recovery smoke (crash -> detect -> autoscale) =="
# End-to-end DESIGN.md §12 loop from the CLI: an unannounced crash
# mid-BSP can only finish via detection + the autoscaled replacement,
# so the grep below doubles as a liveness check on the recovery path.
fault_out=$(./target/release/hbatch simulate --workload mnist --cores 4,4,8 \
    --policy dynamic --sync bsp --iters 60 --seed 2 \
    --faults crash:1@1 --detect 'grace=4,floor=5' --autoscale 'pool=1,cold=1')
for needle in '"suspect"' '"ready"' '"join"'; do
    if ! grep -q -- "$needle" <<<"$fault_out"; then
        echo "tier1: fault smoke output is missing $needle" >&2
        exit 1
    fi
done

echo "== tier1: corruption-guard smoke (corrupt -> reject -> quarantine -> readmit) =="
# End-to-end DESIGN.md §16 loop from the CLI: worker 1's update stream
# turns poisonous mid-run (windowed 100x scale inflation), the update
# guard rejects two strikes, quarantines on the third, and readmits
# after probation — the full lifecycle must appear in the report, so
# the grep below doubles as a liveness check on the data-plane
# recovery path.  Onset, window, and probation are fractions of the
# clean run's measured makespan (same calibration trick as the
# crash->resume smoke below), so the whole lifecycle always fits
# inside the run whatever the workload's absolute time scale.
# --adjust-cost 1 keeps readjustment pauses small relative to the
# makespan, so the fraction-denominated corruption window can't be
# swallowed by a single pause (the simulate default charges 30 s per
# applied readjustment).
guard_args=(--workload mnist --cores 4,4,8 --policy dynamic --sync bsp
    --iters 60 --seed 2 --adjust-cost 1)
clean_out=$(./target/release/hbatch simulate "${guard_args[@]}")
clean_total=$(grep -o '"total_time_s": [0-9.e+-]*' <<<"$clean_out" | head -1 | awk '{print $2}')
corrupt_on=$(awk -v t="$clean_total" 'BEGIN{printf "%.3f", 0.35*t}')
corrupt_dur=$(awk -v t="$clean_total" 'BEGIN{printf "%.3f", 0.45*t}')
probation=$(awk -v t="$clean_total" 'BEGIN{printf "%.3f", 0.5*t}')
guard_out=$(./target/release/hbatch simulate "${guard_args[@]}" \
    --corrupt "1@${corrupt_on}:scale:100:${corrupt_dur}" \
    --guard "norm=8,strikes=3,probation=${probation}")
for needle in '"reject"' '"quarantine"' '"readmit"' '"revoke"' '"join"'; do
    if ! grep -q -- "$needle" <<<"$guard_out"; then
        echo "tier1: corruption smoke output is missing $needle" >&2
        exit 1
    fi
done
# A corruption plan without a guard must be refused up front.
if ./target/release/hbatch simulate --workload mnist --cores 4,4,8 \
    --corrupt '1@8:nan' >/dev/null 2>&1; then
    echo "tier1: corruption without a guard was not refused" >&2
    exit 1
fi

echo "== tier1: batch-policy smoke (pid | optimal | rl) =="
# Every shipped BatchPolicy must complete the same small churned run
# from the CLI.  "pid" is the documented alias for the proportional
# controller and must keep reporting the dynamic label; optimal and rl
# report under their own labels.
for pol in pid optimal rl; do
    pol_out=$(./target/release/hbatch simulate --workload mnist --cores 4,4,8 \
        --policy "$pol" --sync bsp --iters 40 --seed 3 --spot 30:8:1)
    case "$pol" in
        pid) want='/dynamic/' ;;
        *) want="/$pol/" ;;
    esac
    if ! grep -q -- "$want" <<<"$pol_out"; then
        echo "tier1: policy smoke ($pol) label is missing $want" >&2
        exit 1
    fi
    if ! grep -q '"total_time_s"' <<<"$pol_out"; then
        echo "tier1: policy smoke ($pol) produced no report" >&2
        exit 1
    fi
done

echo "== tier1: crash -> resume smoke (bit-identical checkpoint restore) =="
# DESIGN.md §15 end-to-end from the CLI: the same churned run is (a) run
# to completion, (b) killed mid-run by coordinator-crash injection, then
# (c) resumed from the latest durable checkpoint.  The resumed report
# must be byte-identical to the uninterrupted one — the whole point of
# the checkpoint subsystem is that a crash is invisible in the results.
ckpt_dir=$(mktemp -d)
sim_args=(--workload mnist --cores 4,4,8 --policy dynamic --sync bsp
    --iters 50 --seed 4 --spot 30:8:1)
full_out=$(./target/release/hbatch simulate "${sim_args[@]}")
# Crash halfway through the uninterrupted run's virtual makespan, so the
# kill always lands mid-run whatever the workload's time scale.
total=$(grep -o '"total_time_s": [0-9.e+-]*' <<<"$full_out" | head -1 | awk '{print $2}')
crash_t=$(awk -v t="$total" 'BEGIN{printf "%.3f", t/2}')
crash_out=$(./target/release/hbatch simulate "${sim_args[@]}" \
    --checkpoint "$ckpt_dir:0:2" --crash-at "$crash_t")
if ! grep -q 'coordinator crashed' <<<"$crash_out"; then
    echo "tier1: crash injection at t=$crash_t did not stop the coordinator" >&2
    exit 1
fi
resume_out=$(./target/release/hbatch resume --from "$ckpt_dir")
if [[ "$full_out" != "$resume_out" ]]; then
    echo "tier1: resumed report differs from the uninterrupted run" >&2
    diff <(echo "$full_out") <(echo "$resume_out") >&2 || true
    exit 1
fi
rm -rf "$ckpt_dir"

echo "tier1: OK"
