//! Transient-cloud scenario (paper §I–II motivation): a cluster of spot /
//! preemptible workers with interference and preemptions, compared under
//! uniform / static / dynamic batching in the virtual-time simulator.
//!
//! ```bash
//! cargo run --release --example spot_cluster
//! ```
//!
//! Demonstrates the *dynamic* heterogeneity case that motivates the
//! closed-loop controller: open-loop static batching fixes its split at
//! t=0 and cannot follow capacity changes; the proportional controller
//! re-balances after every interference burst / preemption recovery.
//! (The same traces can be attached to a real run — `build_real` — where
//! they integrate over measured PJRT compute; see
//! `tests/engine_integration.rs`.)

use hetero_batch::config::Policy;
use hetero_batch::fault::{AutoscalerCfg, DetectorCfg, FaultPlan};
use hetero_batch::metrics::SpawnAction;
use hetero_batch::session::Session;
use hetero_batch::trace::{AvailTrace, ClusterTraces, MembershipPlan};
use hetero_batch::util::rng::Rng;

fn scenario(policy: Policy, elastic: bool, seed: u64) -> hetero_batch::metrics::RunReport {
    // 3 equal spot VMs — heterogeneity here is purely *dynamic*.
    // Worker 0: heavy colocation interference (drops to 35% capacity).
    // Worker 1: overcommitment epochs (60–80%).
    // Worker 2: one spot preemption at ~20 min, back 2 min later.
    let mut rng = Rng::new(seed ^ 0x5107);
    let traces = ClusterTraces {
        traces: vec![
            AvailTrace::interference(40_000.0, 900.0, 400.0, 0.35, &mut rng),
            AvailTrace::overcommit(40_000.0, 1_500.0, &[0.6, 0.8], &mut rng),
            AvailTrace::spot(40_000.0, 1_200.0, 120.0, &mut rng),
        ],
    };
    let mut builder = Session::builder()
        .model("resnet")
        .cores(&[13, 13, 13])
        .policy(policy)
        .steps(4_000)
        .adjust_cost(10.0)
        .seed(seed);
    if elastic {
        // Elastic membership (DESIGN.md §9): any worker down past a
        // 60 s grace is revoked (mass water-filled onto survivors) and
        // rejoins on recovery — here that covers worker 2's ~2 min
        // spot preemption.
        builder = builder.membership(
            MembershipPlan::from_traces(&traces, 60.0).expect("spot grace"),
        );
    }
    builder
        .traces(traces)
        .build_sim()
        .expect("spot scenario")
        .run()
        .expect("spot run")
}

/// Fleet scale (DESIGN.md §10): a k = 1024 spot fleet with
/// trace-derived churn.  A run this size is what the session loop's
/// O(log k) event scheduling unlocks — under the seed's O(k)-per-event
/// scans, one fleet run cost k²·iters scan work; now the sim finishes
/// in interactive time, so spot-fleet capacity planning sweeps are a
/// for-loop away.  `report_sample` keeps the report from growing
/// O(steps·k).
fn fleet_row() {
    const K: usize = 1024;
    let cores: Vec<usize> = (0..K).map(|i| [4usize, 8, 16][i % 3]).collect();
    // Seeded per-VM preemption traces over a short horizon; any VM down
    // past a half-second grace is revoked and rejoins on recovery.
    let traces = ClusterTraces::spot_cluster(K, 120.0, 40.0, 3.0, 99);
    let plan = MembershipPlan::from_traces(&traces, 0.5).expect("fleet grace");
    let t0 = std::time::Instant::now();
    let r = Session::builder()
        .model("mnist")
        .cores(&cores)
        .policy(Policy::Dynamic)
        .steps(40)
        .adjust_cost(1.0)
        .seed(9)
        // Keep every 8th round whole: the report stays ~5 K records
        // instead of 40 K, with per-worker stats still unbiased.
        .report_sample(8)
        .traces(traces)
        .membership(plan)
        .build_sim()
        .expect("fleet scenario")
        .run()
        .expect("fleet run");
    println!();
    println!("== spot fleet: k = 1024 preemptible VMs, dynamic batching + elastic membership ==");
    println!(
        "{:<12} {:>12} {:>10} {:>12} {:>14}",
        "scenario", "makespan", "epochs", "adjusts", "sim wall-clock"
    );
    println!(
        "{:<12} {:>10.0} s {:>10} {:>12} {:>11.0} ms",
        "spot_fleet",
        r.total_time,
        r.epochs.len(),
        r.adjustments.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
}

/// Autoscaled recovery (DESIGN.md §12): worker 2 crashes *unannounced*
/// mid-run — no membership plan knows about it.  The progress-deadline
/// detector suspects it when it misses its deadline, provisionally
/// retires it through the revocation path, and the autoscaler spawns a
/// replacement from the provisioning pool after a cold start.  The row
/// reports the detection latency and recovery makespan against an
/// oracle run where the same loss was announced via `--spot`-style
/// membership at the crash instant.
fn recovery_row() {
    let build = || {
        Session::builder()
            .model("resnet")
            .cores(&[13, 13, 13])
            .policy(Policy::Dynamic)
            .steps(2_000)
            .adjust_cost(10.0)
            .seed(7)
    };
    let faulted = build()
        .faults(FaultPlan::parse("crash:2@900").expect("fault plan"))
        .detector(DetectorCfg::parse("grace=4,floor=60").expect("detector"))
        .autoscale(AutoscalerCfg::parse("pool=1,cold=120").expect("autoscaler"))
        .build_sim()
        .expect("recovery scenario")
        .run()
        .expect("recovery run");
    let oracle = build()
        .membership(MembershipPlan::new(vec![hetero_batch::trace::MembershipEvent {
            time: 900.0,
            worker: 2,
            kind: hetero_batch::trace::MembershipKind::Revoke,
        }]))
        .build_sim()
        .expect("oracle scenario")
        .run()
        .expect("oracle run");
    let suspect_t = faulted.suspicions.first().map(|s| s.time).unwrap_or(f64::NAN);
    let rejoin_t = faulted
        .spawns
        .iter()
        .find(|s| s.action == SpawnAction::Ready)
        .map(|s| s.time)
        .unwrap_or(f64::NAN);
    println!();
    println!("== autoscaled recovery: unannounced crash at t=900 s, detector + 1-VM pool ==");
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>12}",
        "scenario", "detect_s", "rejoin_s", "makespan", "vs oracle"
    );
    println!(
        "{:<12} {:>10.0} s {:>10.0} s {:>10.0} s {:>11.2}x",
        "crash+as",
        suspect_t - 900.0,
        rejoin_t - 900.0,
        faulted.total_time,
        faulted.total_time / oracle.total_time
    );
    println!();
    println!("the oracle run is told about the loss instantly (membership plan);");
    println!("the faulted run pays detection latency (grace x smoothed iteration");
    println!("time) plus the replacement's cold start, and still finishes within");
    println!("a few percent because survivors absorb the batch mass meanwhile.");
}

fn main() {
    println!("== spot cluster: dynamic heterogeneity (interference + overcommit + preemption) ==");
    println!(
        "{:<10} {:>12} {:>14} {:>12} {:>12}",
        "policy", "time_to_4k", "vs uniform", "adjusts", "wait_frac"
    );
    let mut base = 0.0;
    for (policy, elastic) in [
        (Policy::Uniform, false),
        (Policy::Static, false),
        (Policy::Dynamic, false),
        (Policy::Dynamic, true),
    ] {
        let r = scenario(policy, elastic, 7);
        if policy == Policy::Uniform {
            base = r.total_time;
        }
        let label = if elastic {
            format!("{}+el", policy.label())
        } else {
            policy.label().to_string()
        };
        println!(
            "{:<10} {:>10.0} s {:>13.2}x {:>12} {:>12.3}",
            label,
            r.total_time,
            base / r.total_time,
            r.adjustments.len(),
            r.wait_fraction()
        );
    }
    println!();
    println!("static batching cannot react to capacity changes (its split is");
    println!("fixed at t=0 and the workers start equal, so it IS uniform here);");
    println!("the dynamic controller re-balances after each capacity shift, and");
    println!("'+el' additionally revokes a preempted worker after a 60 s grace");
    println!("instead of stalling the barrier until its VM returns.");
    fleet_row();
    recovery_row();
}
