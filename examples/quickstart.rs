//! Quickstart: train a real model on a simulated heterogeneous cluster.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Opens the AOT artifact bundle, builds a 2-worker cluster where worker 1
//! has 4x the capacity of worker 0, and trains the MNIST-stand-in MLP for
//! 40 BSP rounds under the paper's dynamic batching policy.  Watch the
//! controller move batch share to the fast worker while the loss falls.
//!
//! The same `Session::builder()` drives the virtual-time simulator — swap
//! `build_real(&mut runtime)` for `build_sim()` (and `model("mnist")`) to
//! rerun this experiment without artifacts.

use hetero_batch::config::Policy;
use hetero_batch::controller::ControllerCfg;
use hetero_batch::runtime::Runtime;
use hetero_batch::session::Session;

fn main() -> anyhow::Result<()> {
    // 1. The runtime loads artifacts/manifest.json and lazily compiles one
    //    executable per (model, batch-bucket) on the PJRT CPU client.
    let mut runtime = Runtime::open("artifacts")?;

    // 2–3. A heterogeneous cluster — 4-core and 16-core workers, capacity
    //    difference injected virtually — trained through one session.
    let cores = [4usize, 16];
    let report = Session::builder()
        .model("mlp")
        .cores(&cores)
        .policy(Policy::Dynamic)
        .controller(ControllerCfg {
            min_obs: 3,
            ..ControllerCfg::default()
        })
        .steps(40)
        .seed(0)
        .build_real(&mut runtime)?
        .run()?;

    // 4. Results.
    println!("== quickstart: dynamic batching on a 4x-heterogeneous cluster ==");
    for (i, (t, step, loss)) in report.losses.iter().enumerate() {
        if i % 5 == 0 || i + 1 == report.losses.len() {
            println!("  step {step:>3}  t={t:>6.2}s  loss={loss:.4}");
        }
    }
    println!("batch adjustments: {}", report.adjustments.len());
    for adj in &report.adjustments {
        println!("  at step {:>3}: {:?}", adj.iter, adj.batches);
    }
    if let Some(b) = report.final_batches() {
        println!("final allocation: {b:?}  (worker cores: {cores:?})");
    }
    println!(
        "iteration-gap p95 (max-min)/mean: {:.3}",
        report.iteration_gap(cores.len())
    );
    Ok(())
}
