//! The paper's §IV-B mixed-device experiment: a Tesla P100 GPU and a
//! 48-core Xeon training one model together, plus the cloud 2×T4 + 2×P4
//! cluster — uniform vs static-variable vs dynamic batching.
//!
//! ```bash
//! cargo run --release --example mixed_gpu_cpu
//! ```

use hetero_batch::cluster::{cloud_gpu_cluster, mixed_gpu_cpu_cluster, WorkerSpec};
use hetero_batch::config::Policy;
use hetero_batch::session::Session;

fn run(
    workload: &str,
    workers: Vec<WorkerSpec>,
    policy: Policy,
) -> hetero_batch::metrics::RunReport {
    Session::builder()
        .model(workload)
        .workers(workers)
        .policy(policy)
        .steps(0) // run to the workload's accuracy target
        .adjust_cost(20.0)
        .build_sim()
        .expect("mixed-device scenario")
        .run()
        .expect("mixed-device run")
}

fn main() {
    println!("== P100 + 48-core Xeon (paper Fig. 7a) ==");
    for workload in ["resnet", "mnist"] {
        let mut base = 0.0;
        for policy in [Policy::Uniform, Policy::Static, Policy::Dynamic] {
            let r = run(workload, mixed_gpu_cpu_cluster(), policy);
            if policy == Policy::Uniform {
                base = r.total_time;
            }
            let batches = r
                .final_batches()
                .map(|b| format!("{b:?}"))
                .unwrap_or_else(|| "open-loop".into());
            println!(
                "  {workload:<8} {:<8} {:>9.0} s  {:>5.2}x   final batches: {batches}",
                policy.label(),
                r.total_time,
                base / r.total_time
            );
        }
    }

    println!();
    println!("== cloud cluster: 2x T4 + 2x P4, ResNet (paper: 90 min -> 20 min) ==");
    let mut base = 0.0;
    for policy in [Policy::Uniform, Policy::Static, Policy::Dynamic] {
        let r = run("resnet", cloud_gpu_cluster(), policy);
        if policy == Policy::Uniform {
            base = r.total_time;
        }
        println!(
            "  {:<8} {:>7.1} min  {:>5.2}x",
            policy.label(),
            r.total_time / 60.0,
            base / r.total_time
        );
    }
    println!();
    println!("the T4:P4 half-precision FLOPs ratio is ~12x, so uniform batching");
    println!("stalls both T4s behind the P4 stragglers; variable batching");
    println!("restores throughput-proportional work.");
}
