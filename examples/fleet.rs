//! Multi-tenant fleet walkthrough (DESIGN.md §13): three training jobs
//! share one 10-slot elastic worker pool under strict priority, and a
//! late-arriving high-priority job preempts the early tenants down to
//! their floors — through the same membership revocation path spot
//! churn uses — then hands the slots back when it finishes.
//!
//! ```bash
//! cargo run --release --example fleet
//! ```
//!
//! Also demonstrates the isolation invariant: the same three jobs run
//! uncontended (capacity = total demand) produce reports bitwise
//! identical to standalone runs — the fleet layer only ever *arbitrates*,
//! it never perturbs a job it doesn't have to shrink.

use hetero_batch::config::Policy;
use hetero_batch::fleet::{job_seed, ArbiterPolicy, FleetBuilder, JobSpec};
use hetero_batch::session::{Session, SessionBuilder};
use hetero_batch::trace::MembershipKind;

fn job(seed: u64, cores: &[usize], steps: u64) -> SessionBuilder {
    Session::builder()
        .model("mnist")
        .cores(cores)
        .policy(Policy::Dynamic)
        .steps(steps)
        .adjust_cost(1.0)
        .seed(seed)
}

fn specs() -> Vec<JobSpec> {
    // Two long background jobs from t=0; derived per-job seed streams
    // keep them decorrelated under any interleaving.
    let mut low0 = JobSpec::new("batch-a", job(job_seed(1, 0), &[4, 8, 4, 8], 300));
    low0.priority = 0;
    let mut low1 = JobSpec::new("batch-b", job(job_seed(1, 1), &[4, 8, 4, 8], 300));
    low1.priority = 0;
    // A short high-priority job arriving mid-run.
    let mut hi = JobSpec::new("urgent", job(job_seed(1, 2), &[8, 8, 8, 8, 8, 8], 30));
    hi.priority = 9;
    hi.arrival = 20.0;
    vec![low0, low1, hi]
}

fn main() {
    // --- contended: 10 slots for 14 ranks of demand, strict priority.
    let report = FleetBuilder::new()
        .capacity(10)
        .policy(ArbiterPolicy::Priority)
        .jobs(specs())
        .build()
        .expect("fleet config")
        .run()
        .expect("fleet run");

    println!(
        "fleet: capacity {} policy {} — makespan {:.0}s, p50 {:.0}s, p99 {:.0}s, utilization {:.0}%",
        report.capacity,
        report.policy.label(),
        report.makespan,
        report.completion_p50,
        report.completion_p99,
        100.0 * report.utilization,
    );
    for o in &report.jobs {
        let revokes = o
            .report
            .epochs
            .iter()
            .filter(|e| e.kind == MembershipKind::Revoke)
            .count();
        println!(
            "  {:8} arrival {:5.0}s  admitted {:5.0}s  done {:6.0}s  \
             granted {}  preempted {} ranks ({} revoke epochs), re-granted {}",
            o.name,
            o.arrival,
            o.admission,
            o.completion,
            o.granted_final,
            o.fleet_preemptions,
            revokes,
            o.fleet_regrants,
        );
    }

    // --- uncontended: same jobs, capacity = demand — bitwise isolation.
    let free = FleetBuilder::new()
        .jobs(specs())
        .build()
        .expect("fleet config")
        .run()
        .expect("fleet run");
    let isolated = specs().iter().zip(&free.jobs).all(|(spec, o)| {
        let solo = spec
            .builder
            .clone()
            .build_sim()
            .expect("standalone build")
            .run()
            .expect("standalone run");
        o.report.bitwise_eq(&solo)
    });
    println!(
        "uncontended fleet bitwise-identical to standalone runs: {isolated}"
    );
    assert!(isolated, "isolation invariant violated");
}
