//! End-to-end driver (EXPERIMENTS.md §E2E): trains a real transformer LM
//! through the full stack — synthetic Markov corpus → per-worker AOT
//! Pallas/XLA train steps → λ-weighted aggregation (Eq. 2–3) → Adam on the
//! Rust parameter server → dynamic batch controller — on a heterogeneous
//! 3-worker cluster, and logs the loss curve.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example e2e_train -- [steps] [model]
//! ```
//!
//! Defaults: 300 steps of the registry `transformer` (~0.8M params,
//! vocab 512 / seq 64).  Pass `transformer_e2e` as the second arg after
//! building the ~12M-param preset (`cd python && python -m compile.aot
//! --e2e --models ''`) for the heavyweight version of the same run.
//!
//! The corpus is an order-1 Markov chain with fanout 4, so loss should
//! fall from ~ln(512) ≈ 6.2 toward the chain's entropy floor ln(4) ≈ 1.39
//! — crossing below the unigram floor proves the model is learning real
//! sequence structure through the Pallas matmul kernels.

use std::io::Write;

use hetero_batch::config::Policy;
use hetero_batch::controller::ControllerCfg;
use hetero_batch::runtime::Runtime;
use hetero_batch::session::Session;
use hetero_batch::util::csv::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let model = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "transformer".to_string());

    let mut runtime = Runtime::open("artifacts")?;
    let cores = [6usize, 10, 24]; // H-level 4 cluster

    println!("== e2e: {model} on a (6,10,24)-core heterogeneous cluster ==");
    let m = runtime.model(&model)?;
    println!(
        "params: {} ({} tensors)   buckets: {:?}   steps: {steps}",
        m.param_total,
        m.params.len(),
        m.buckets
    );

    let t0 = std::time::Instant::now();
    let report = Session::builder()
        .model(&model)
        .cores(&cores)
        .policy(Policy::Dynamic)
        .controller(ControllerCfg {
            min_obs: 3,
            ..ControllerCfg::default()
        })
        .steps(steps)
        .seed(0)
        .pool_threads(8)
        .build_real(&mut runtime)?
        .run()?;
    let wall = t0.elapsed();

    // Loss curve.
    let mut curve = Table::new(&["step", "wall_s", "loss"]);
    for &(t, step, loss) in &report.losses {
        curve.rowf(&[&step, &format!("{t:.2}"), &format!("{loss:.4}")]);
        if step % 25 == 0 || step + 1 == report.total_iters {
            println!("  step {step:>4}  loss {loss:.4}");
        }
    }
    std::fs::create_dir_all("figures_out")?;
    let csv_path = format!("figures_out/e2e_{model}_loss.csv");
    curve.save(&csv_path)?;

    let first = report.losses.first().map(|l| l.2).unwrap_or(f64::NAN);
    let last = report.losses.last().map(|l| l.2).unwrap_or(f64::NAN);
    println!("---");
    println!("wall time: {wall:?}  ({} steps)", report.total_iters);
    println!("loss: {first:.4} -> {last:.4}  (floor: ln4 = {:.4})", 4f64.ln());
    println!("controller adjustments: {}", report.adjustments.len());
    if let Some(b) = report.final_batches() {
        println!("final batch buckets: {b:?}  (cores {cores:?})");
    }
    println!("loss curve -> {csv_path}");

    // JSON report for EXPERIMENTS.md.
    let json_path = format!("figures_out/e2e_{model}_report.json");
    let mut f = std::fs::File::create(&json_path)?;
    f.write_all(report.to_json(cores.len()).to_pretty().as_bytes())?;
    println!("full report -> {json_path}");

    // The e2e contract: structure was actually learned.
    if steps >= 200 {
        assert!(
            last < first * 0.55,
            "e2e loss did not fall far enough: {first} -> {last}"
        );
    }
    Ok(())
}
